"""Backlog queue (paper §4.1.5) — storage for temporarily postponed requests.

The paper: "The backlog queue is used to store communication requests that
cannot be immediately submitted and cannot be back-propagated to the user
... LCI expects such scenarios to be rare, so we implement it with a simple
C++ queue with a spinlock. An atomic flag prevents the progress engine from
unnecessarily polling an empty backlog queue."

Host-side :class:`BacklogQueue` keeps that shape — and, since the
concurrency subsystem landed, the paper's exact locking: a deque guarded
by a spinlock-style :class:`~repro.core.concurrency.TryLock`, with a real
:class:`~repro.core.concurrency.AtomicFlag` empty-flag fast path so the
progress engine never takes the lock just to learn the queue is empty.
An optional capacity bound surfaces ``retry(RETRY_BACKLOG_FULL)`` on
``push`` — but never on ``push_front``: a requeue of an already-popped
item (a rejected signal redelivery, a still-full fabric) must not fail,
so the head push bypasses the capacity check.

The functional ring (:func:`init_ring` / :func:`ring_push` /
:func:`ring_pop`) is the in-graph variant used by the serving scheduler's
admission queue and the MoE overflow ledger.  It doubles as the fixed-size
FAA completion-queue implementation (paper §4.1.4: "a hand-written
Fetch-And-Add-based fix-sized array") — a CQ *is* an MPSC ring here.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .concurrency.atomics import AtomicFlag
from .concurrency.locks import TryLock
from .status import ErrorCode, Status, done, retry


class BacklogQueue:
    """Host-side backlog: thread-safe FIFO of postponed descriptors.

    Lock granularity (DESIGN.md §10): one spinlock per queue — the paper
    expects the backlog to be nearly always empty, so a finer structure
    would buy nothing.  The :attr:`empty_flag` read is lock-free.
    """

    def __init__(self, capacity: Optional[int] = None):
        self._q: collections.deque = collections.deque()
        self.capacity = capacity
        self.max_depth = 0          # telemetry: paper expects this to stay ~0
        self.lock = TryLock(name="backlog")
        self._empty = AtomicFlag(init=True)

    @property
    def empty_flag(self) -> bool:
        """The atomic-flag fast path: progress() checks this before polling
        (and before taking the lock)."""
        return self._empty.is_set()

    def push(self, item: Any) -> Status:
        with self.lock:
            if self.capacity is not None and len(self._q) >= self.capacity:
                return retry(ErrorCode.RETRY_BACKLOG_FULL)
            self._q.append(item)
            self.max_depth = max(self.max_depth, len(self._q))
            self._empty.clear()
        return done()

    def push_front(self, item: Any) -> Status:
        """Requeue at the head: a popped item that could not be processed
        goes back to its original position, preserving FIFO delivery.

        Never fails: the item was already accounted for when it was first
        pushed (or is owed a redelivery, e.g. a signal a full CQ rejected),
        so the capacity bound does not apply — rejecting a requeue would
        drop a completion the runtime has promised to deliver."""
        with self.lock:
            self._q.appendleft(item)
            self.max_depth = max(self.max_depth, len(self._q))
            self._empty.clear()
        return done()

    def pop(self) -> tuple[Any, Status]:
        if self._empty.is_set():                 # lock-free fast path
            return None, retry(ErrorCode.RETRY_LOCKED)
        with self.lock:
            if not self._q:
                return None, retry(ErrorCode.RETRY_LOCKED)
            item = self._q.popleft()
            if not self._q:
                self._empty.test_and_set()
            return item, done()

    def __len__(self) -> int:
        return len(self._q)


# ---------------------------------------------------------------------------
# Functional MPSC/MPMC ring (fixed-size, FAA-style head/tail counters).
#
#   buf  (cap, width) int32/float payload records
#   head ()           int32  -- next pop position (monotone counter)
#   tail ()           int32  -- next push position (monotone counter)
#
# Indices wrap modulo cap; (tail - head) is the live count.  Inside a jitted
# program pushes are sequenced by dataflow, which makes the monotone-counter
# design exact rather than merely linearizable.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ring:
    buf: jax.Array
    head: jax.Array
    tail: jax.Array


jax.tree_util.register_pytree_node(
    Ring,
    lambda r: ((r.buf, r.head, r.tail), None),
    lambda _, c: Ring(*c))


def init_ring(cap: int, width: int, dtype=jnp.int32) -> Ring:
    return Ring(buf=jnp.zeros((cap, width), dtype),
                head=jnp.zeros((), jnp.int32),
                tail=jnp.zeros((), jnp.int32))


def ring_push(ring: Ring, record) -> tuple[Ring, jax.Array]:
    """Push one record. Returns (ring', status): 0 ok, 1 full (retry)."""
    cap = ring.buf.shape[0]
    live = ring.tail - ring.head
    ok = live < cap
    pos = ring.tail % cap
    record = jnp.asarray(record, ring.buf.dtype)
    buf = ring.buf.at[pos].set(jnp.where(ok, record, ring.buf[pos]))
    return (Ring(buf, ring.head, ring.tail + jnp.where(ok, 1, 0)),
            jnp.where(ok, 0, 1).astype(jnp.int32))


def ring_pop(ring: Ring) -> tuple[Ring, jax.Array, jax.Array]:
    """Pop one record. Returns (ring', record, status): 0 ok, 1 empty."""
    cap = ring.buf.shape[0]
    ok = ring.tail > ring.head
    pos = ring.head % cap
    rec = jnp.where(ok, ring.buf[pos], jnp.zeros_like(ring.buf[pos]))
    return (Ring(ring.buf, ring.head + jnp.where(ok, 1, 0), ring.tail),
            rec, jnp.where(ok, 0, 1).astype(jnp.int32))


def ring_size(ring: Ring) -> jax.Array:
    return ring.tail - ring.head
