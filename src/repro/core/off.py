"""The Objectified Flexible Function (OFF) idiom (paper §3.1), in Python.

LCI's C++ OFF lets callers set optional arguments in any order::

    post_send_x(rank, buf, size, tag, comp).device(device)();
    post_send_x(...).matching_policy(rank_only).device(device)();

Python has kwargs, but the OFF idiom buys three things we keep:

1. *Incremental refinement* — an OFF object is a value; a client can build a
   partially-configured op, hand it around, and finish it elsewhere.
2. *Validation at set-time* — unknown options fail at the ``.option()`` call
   site, not deep inside the runtime.
3. *Uniform introspection* — benchmarks/tests can enumerate the option set.

The C++ version is generated from a DSL by a Python script; here the
decorator plays that role: it manufactures the ``<name>_x`` builder class
from the wrapped function's signature.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable


class OffBuilder:
    """Callable builder: ``off(positional...).opt(v).opt2(v)()``."""

    __slots__ = ("_fn", "_sig", "_args", "_opts", "_allowed")

    def __init__(self, fn: Callable, sig: inspect.Signature,
                 allowed: dict[str, inspect.Parameter], args: tuple):
        self._fn = fn
        self._sig = sig                # computed once, at decoration time
        self._args = args
        self._opts: dict[str, Any] = {}
        self._allowed = allowed

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        if name not in self._allowed:
            raise TypeError(
                f"{self._fn.__name__}_x has no optional argument {name!r}; "
                f"valid options: {sorted(self._allowed)}")

        def setter(value):
            self._opts[name] = value
            return self

        return setter

    def options(self) -> dict[str, Any]:
        """Introspection: currently-set optional arguments."""
        return dict(self._opts)

    def is_set(self, name: str) -> bool:
        """True if ``name`` is already bound — via ``.name(v)`` or
        positionally.  Lets a holder of a deferred op (e.g. a completion
        graph owning a comm node) check an option is still free to set."""
        if name in self._opts:
            return True
        try:
            bound = self._sig.bind_partial(*self._args)
        except TypeError:
            return False
        return name in bound.arguments

    def get(self, name: str, default: Any = None) -> Any:
        """Current bound value of an argument (positional or option)."""
        if name in self._opts:
            return self._opts[name]
        try:
            bound = self._sig.bind_partial(*self._args)
        except TypeError:
            return default
        return bound.arguments.get(name, default)

    def set(self, name: str, value: Any) -> "OffBuilder":
        """Bind ``name`` even if it was already given positionally (the
        attribute sugar would collide with the positional slot)."""
        if name not in self._allowed:
            raise TypeError(
                f"{self._fn.__name__}_x has no optional argument {name!r}; "
                f"valid options: {sorted(self._allowed)}")
        params = list(self._sig.parameters)
        idx = params.index(name)
        if idx < len(self._args):
            self._args = self._args[:idx] + (value,) + self._args[idx + 1:]
        else:
            self._opts[name] = value
        return self

    def __call__(self):
        return self._fn(*self._args, **self._opts)

    def batch(self, collector=None):
        """Defer this op into a :class:`~repro.core.post.PostBatch`
        doorbell instead of firing it now::

            b = post_send_x(rt, peer, buf).endpoint(ep).batch()
            post_send_x(rt, peer, buf2).endpoint(ep).batch(b)
            statuses = b.flush()          # one coalesced doorbell

        With no argument a fresh batch is created; passing an existing
        batch appends to it.  Returns the batch (for further adds /
        ``flush``).  Only ``post_*`` operations can ride a doorbell —
        anything else fails at ``flush`` time."""
        if collector is None:
            from .post import PostBatch   # late: post.py imports this module
            collector = PostBatch()
        return collector.add(self)


def off(fn: Callable) -> Callable:
    """Decorator: attach an OFF variant as ``fn.x`` (the ``_x`` suffix).

    Positional-or-keyword params without defaults are the positional
    arguments; everything with a default becomes a settable option.
    """
    sig = inspect.signature(fn)
    optional = {
        name: p for name, p in sig.parameters.items()
        if p.default is not inspect.Parameter.empty
        or p.kind == inspect.Parameter.KEYWORD_ONLY
    }

    def make_builder(*args) -> OffBuilder:
        return OffBuilder(fn, sig, optional, args)

    make_builder.__name__ = fn.__name__ + "_x"
    make_builder.__doc__ = (f"OFF variant of {fn.__name__}: set optional "
                            f"arguments in any order, then call with ().")
    fn.x = make_builder  # type: ignore[attr-defined]
    return fn
