"""Packet pool (paper §4.1.2) — fixed-size pre-registered buffer management.

The paper's packet pool is a collection of per-thread deques of fixed-size
pre-registered buffers ("packets"):

* ``get`` pops from the tail of the local deque; when empty it steals *half*
  of a randomly selected victim's packets from the head (one attempt, then
  the nonblocking ``get`` fails and ``post_comm`` returns ``retry``).
* ``put`` pushes to the tail (cache locality: hot packets are reused first).
* stealing happens at the head end (cold packets), local traffic at the tail.

Two implementations, mirroring :mod:`repro.core.matching`:

1. :class:`HostPacketPool` — Python deques, used by the host-side runtime
   (message staging for the buffer-copy protocol, serving KV page allocator,
   aggregation buffers).  Since the concurrency subsystem landed this is
   the paper's §4.1.2 design verbatim: each lane's deque is guarded by a
   spinlock-style :class:`~repro.core.concurrency.TryLock`; local get/put
   take their own lane's lock (blocking spin — a lane is rarely contended
   by design), while a steal attempt *try-locks* the victim and, on
   failure, gives up immediately so the nonblocking ``get`` surfaces
   ``retry(RETRY_NOPACKET)`` rather than waiting (paper: "``get`` can be
   nonblocking and will return a nullptr when it fails the first packet
   stealing attempts").  Holding one's own lane lock while try-locking a
   victim cannot deadlock: the second acquisition never blocks.
2. Functional jnp pool (:func:`init_pool` / :func:`pool_get` /
   :func:`pool_put`) — a fixed-geometry slot pool living inside jitted
   programs.  Used for MoE expert-capacity slots and paged-KV page
   allocation, and exercised by the Fig-5 resource benchmark.

Status protocol: ``get`` returns packet id ``-1`` + ``retry`` status on
exhaustion (paper: "``get`` can be nonblocking and will return a nullptr
when it fails the first packet stealing attempts").
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import attrs as _attrs
from .concurrency.atomics import AtomicCounter
from .concurrency.locks import TryLock, aggregate_lock_stats
from .status import ErrorCode, Status, done, retry
from .telemetry import NULL_TELEMETRY

#: attrs the host pool resolves at alloc time
POOL_ATTRS = ("pool_lanes", "packets_per_lane", "packet_bytes")


class HostPacketPool(_attrs.AttrResource):
    """Host-side packet pool: per-lane locked deques + try-lock steal-half.

    ``n_lanes`` plays the role of the paper's thread count; each lane owns a
    deque seeded with ``packets_per_lane`` packet ids.  Packets are plain
    integer ids into a backing buffer table (``buffer_of``), so "allocation"
    never copies.  Every deque (and its victim-selection RNG) is protected
    by that lane's :class:`TryLock`; counters are atomic so telemetry stays
    exact under concurrent get/put/steal.
    """

    def __init__(self, n_lanes: int, packets_per_lane: int,
                 packet_bytes: int = 8192, seed: int = 0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 tele=None):
        self.n_lanes = n_lanes
        self.packet_bytes = packet_bytes
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"pool_lanes": n_lanes, "packets_per_lane": packets_per_lane,
             "packet_bytes": packet_bytes}))
        self._export_attr("width", lambda: self.n_lanes)
        self._export_attr("free_packets", self.free_packets)
        self._export_attr("steals", lambda: self.steals)
        self._export_attr("steal_lock_failures",
                          lambda: self.steal_lock_failures)
        self._export_attr("contention",
                          lambda: aggregate_lock_stats(self.locks))
        self._export_attr("telemetry", self._telemetry_block)
        self.n_packets = n_lanes * packets_per_lane
        self._deques = [
            collections.deque(range(i * packets_per_lane,
                                    (i + 1) * packets_per_lane))
            for i in range(n_lanes)
        ]
        self.locks = [TryLock(name=f"pool/lane{i}") for i in range(n_lanes)]
        # per-lane RNGs: victim selection happens under the lane lock, so
        # a per-lane generator is race-free without further locking
        self._rngs = [np.random.default_rng(seed + i) for i in range(n_lanes)]
        # pre-registered fixed-size buffers (the paper registers them with
        # the NIC; here registration == preallocation)
        self.buffer_of = [bytearray(packet_bytes) for _ in range(self.n_packets)]
        self._steals = AtomicCounter()
        self._gets = AtomicCounter()
        self._puts = AtomicCounter()
        self._steal_lock_failures = AtomicCounter()

    # counters stay plain ints to callers (tests compare with ==)
    @property
    def steals(self) -> int:
        return self._steals.load()

    @property
    def gets(self) -> int:
        return self._gets.load()

    @property
    def puts(self) -> int:
        return self._puts.load()

    @property
    def steal_lock_failures(self) -> int:
        """Steal attempts abandoned because the victim's lock was held."""
        return self._steal_lock_failures.load()

    def get(self, lane: int) -> tuple[int, Status]:
        """Pop a packet id; one try-lock-guarded steal attempt on local
        exhaustion, failing to ``retry(RETRY_NOPACKET)`` (never blocking).
        The scalar get IS a burst of one — same locking, same steal."""
        ids, st = self.get_n(lane, 1)
        return (ids[0] if ids else -1), st

    def _steal_half_locked(self, lane: int) -> bool:
        """One nonblocking steal attempt into ``lane`` (whose lock the
        caller holds): try-lock a random victim — never self, that would
        waste the single attempt — and move half its deque, head end to
        head end.  False when the victim was contended or empty."""
        victim = (lane + 1
                  + int(self._rngs[lane].integers(self.n_lanes - 1))) \
            % self.n_lanes
        vlock = self.locks[victim]
        if not vlock.try_acquire():
            # the paper's nonblocking get: a contended victim is a
            # failed attempt, not a wait
            self._steal_lock_failures.fetch_add(1)
            return False
        try:
            vdq = self._deques[victim]
            n_steal = len(vdq) // 2
            if n_steal == 0:
                return False
            self._steals.fetch_add(1)
            dq = self._deques[lane]
            for _ in range(n_steal):
                dq.appendleft(vdq.popleft())     # head end on both sides
        finally:
            vlock.release()
        return True

    def get_n(self, lane: int, n: int) -> tuple[list[int], Status]:
        """Burst ``get`` (paper §4.3: amortize per-message costs): pop up
        to ``n`` packet ids under ONE lane-lock acquisition — one lock
        round-trip grabs a whole doorbell's worth of packets instead of
        ``n`` separate get() calls.

        Returns ``(ids, status)``; ``status`` is ``done`` when all ``n``
        were obtained, else ``retry(RETRY_NOPACKET)`` with however many
        packets *were* available (possibly zero).  A short grab is how a
        mid-burst pool exhaustion splits a doorbell: the caller posts the
        prefix it has packets for and retries the rest.  At most one
        try-lock-guarded steal attempt is made."""
        if n <= 0:
            return [], done()
        tele = self.tele
        if tele.timers_on:
            with tele.span("pool.get"):
                return self._get_n_locked(lane, n)
        return self._get_n_locked(lane, n)

    def _get_n_locked(self, lane: int, n: int) -> tuple[list[int], Status]:
        self._gets.fetch_add(1)
        dq = self._deques[lane]
        out: list[int] = []
        with self.locks[lane]:
            while dq and len(out) < n:
                out.append(dq.pop())             # tail end: cache locality
            if len(out) == n:
                return out, done()
            if self.n_lanes == 1 or not self._steal_half_locked(lane):
                return out, retry(ErrorCode.RETRY_NOPACKET)
            while dq and len(out) < n:
                out.append(dq.pop())
            if len(out) == n:
                return out, done()
            return out, retry(ErrorCode.RETRY_NOPACKET)

    def put(self, lane: int, packet: int) -> Status:
        tele = self.tele
        if tele.timers_on:
            with tele.span("pool.put"):
                return self._put_locked(lane, packet)
        return self._put_locked(lane, packet)

    def _put_locked(self, lane: int, packet: int) -> Status:
        self._puts.fetch_add(1)
        with self.locks[lane]:
            self._deques[lane].append(packet)    # tail end
        return done()

    def put_n(self, lane: int, packets: Sequence[int]) -> Status:
        """Burst ``put``: return a batch of packets under one lane-lock
        acquisition (the progress engine's batched source-completion
        sweep returns a whole drain's packets at once)."""
        if not packets:
            return done()
        tele = self.tele
        if tele.timers_on:
            with tele.span("pool.put"):
                return self._put_n_locked(lane, packets)
        return self._put_n_locked(lane, packets)

    def _put_n_locked(self, lane: int, packets: Sequence[int]) -> Status:
        self._puts.fetch_add(1)
        with self.locks[lane]:
            self._deques[lane].extend(packets)   # tail end, post order
        return done()

    def free_packets(self) -> int:
        return sum(len(d) for d in self._deques)

    def lock_stats(self) -> list[dict]:
        """Per-lane lock telemetry (contention evidence for benchmarks)."""
        return [lk.stats() for lk in self.locks]

    def telemetry_counters(self) -> dict:
        """This pool's legacy counters, for the unified snapshot (the
        owning runtime attaches this under the ``pool.`` prefix)."""
        locks = aggregate_lock_stats(self.locks)
        return {"gets": self.gets, "puts": self.puts,
                "steals": self.steals,
                "steal_lock_failures": self.steal_lock_failures,
                "lock_contentions": locks["contentions"],
                "free_packets": self.free_packets()}

    def _telemetry_block(self) -> dict:
        return {"level": self.tele.level,
                "counters": {f"pool.{k}": v
                             for k, v in self.telemetry_counters().items()}}


# ---------------------------------------------------------------------------
# Functional (in-graph) slot pool.
#
# Geometry: ``n_lanes`` lanes x ``lane_cap`` slots holding packet ids.
#   slots (n_lanes, lane_cap) int32  -- packet ids, -1 == empty position
#   count (n_lanes,)          int32  -- live entries per lane (stack top)
#
# Each lane is a *stack* (the deque's tail end); stealing takes the bottom
# half of the victim's stack (the head end), preserving the paper's
# cache-locality split.  All ops are O(lane_cap) vectorized.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SlotPool:
    slots: jax.Array
    count: jax.Array


jax.tree_util.register_pytree_node(
    SlotPool,
    lambda p: ((p.slots, p.count), None),
    lambda _, c: SlotPool(*c))


def init_pool(n_lanes: int, packets_per_lane: int,
              lane_cap: Optional[int] = None) -> SlotPool:
    """Seed each lane with its own contiguous packet-id range."""
    cap = lane_cap or n_lanes * packets_per_lane   # worst case: all in one lane
    ids = np.full((n_lanes, cap), -1, np.int32)
    for i in range(n_lanes):
        ids[i, :packets_per_lane] = np.arange(
            i * packets_per_lane, (i + 1) * packets_per_lane, dtype=np.int32)
    return SlotPool(slots=jnp.asarray(ids),
                    count=jnp.full((n_lanes,), packets_per_lane, jnp.int32))


def pool_get(pool: SlotPool, lane, steal_seed) -> tuple[SlotPool, jax.Array,
                                                        jax.Array]:
    """Functional ``get``: returns (pool', packet_id, status).

    packet_id == -1 and status == IN_GRAPH_RETRY(1) when both the local pop
    and the single steal attempt fail, mirroring the host pool.
    """
    n_lanes, cap = pool.slots.shape
    lane = jnp.asarray(lane, jnp.int32)
    cnt = pool.count[lane]

    # --- fast path: local pop from the stack top (deque tail) -------------
    def local_pop(p: SlotPool):
        top = p.count[lane] - 1
        pid = p.slots[lane, top]
        return (SlotPool(p.slots.at[lane, top].set(-1),
                         p.count.at[lane].add(-1)),
                pid, jnp.int32(0))

    # --- slow path: steal half from a pseudo-random victim ----------------
    def steal(p: SlotPool):
        # victim selection matches the host pool:
        #   (lane + 1 + (seed % max(n_lanes-1, 1))) % n_lanes
        # parenthesized so the offset is lane+1 plus a value in
        # [0, n_lanes-2] — never lane itself; jnp.remainder guards
        # negative seeds (result carries the divisor's sign, so the
        # offset stays non-negative)
        offset = jnp.remainder(jnp.asarray(steal_seed, jnp.int32),
                               jnp.maximum(n_lanes - 1, 1))
        victim = (lane + 1 + offset) % n_lanes
        vcnt = p.count[victim]
        n_steal = vcnt // 2
        ok = (n_steal > 0) & (victim != lane)

        idx = jnp.arange(cap, dtype=jnp.int32)
        take = idx < n_steal                       # victim head end
        stolen = jnp.where(take, p.slots[victim], -1)
        # compact the victim: shift the remaining entries down
        remaining = jnp.where((idx >= n_steal) & (idx < vcnt),
                              p.slots[victim], -1)
        shifted = jnp.roll(remaining, -n_steal)
        new_victim = jnp.where(ok, shifted, p.slots[victim])
        # prepend stolen packets at our head (positions [0, n_steal) shift up)
        my = p.slots[lane]
        my_shift = jnp.roll(my, n_steal)
        pos = idx < n_steal
        new_mine = jnp.where(ok, jnp.where(pos, stolen, my_shift), my)

        slots = p.slots.at[victim].set(new_victim).at[lane].set(new_mine)
        count = (p.count.at[victim].add(jnp.where(ok, -n_steal, 0))
                 .at[lane].add(jnp.where(ok, n_steal, 0)))
        p2 = SlotPool(slots, count)

        def pop_after(p3):
            return local_pop(p3)

        def fail(p3):
            return p3, jnp.int32(-1), jnp.int32(1)   # retry

        return jax.lax.cond(ok, pop_after, fail, p2)

    return jax.lax.cond(cnt > 0, local_pop, steal, pool)


def pool_get_n(pool: SlotPool, lane, n: int, steal_seed
               ) -> tuple[SlotPool, jax.Array, jax.Array, jax.Array]:
    """Functional burst ``get``: returns (pool', ids, got, status).

    ``n`` is static (it shapes the output): ``ids`` is ``(n,)`` int32 in
    pop order (stack top first), padded with ``-1``; ``got`` is the number
    of valid ids; ``status`` is 0 when the full burst was satisfied, else
    ``IN_GRAPH_RETRY`` (a short grab — the doorbell-splitting case).
    Mirrors :meth:`HostPacketPool.get_n`: at most one steal attempt, and
    only when the local lane cannot satisfy the burst alone.
    """
    n_lanes, cap = pool.slots.shape
    lane = jnp.asarray(lane, jnp.int32)

    def steal(p: SlotPool) -> SlotPool:
        # identical victim selection / head-half transfer as pool_get,
        # except the transfer is clamped to our lane's remaining room:
        # unlike the scalar get (which only steals into an empty lane),
        # the burst get steals while still holding packets, and an
        # unclamped roll would wrap live slots past lane_cap —
        # duplicating some ids and losing others
        offset = jnp.remainder(jnp.asarray(steal_seed, jnp.int32),
                               jnp.maximum(n_lanes - 1, 1))
        victim = (lane + 1 + offset) % n_lanes
        vcnt = p.count[victim]
        n_steal = jnp.minimum(vcnt // 2, cap - p.count[lane])
        ok = (n_steal > 0) & (victim != lane)
        idx = jnp.arange(cap, dtype=jnp.int32)
        stolen = jnp.where(idx < n_steal, p.slots[victim], -1)
        remaining = jnp.where((idx >= n_steal) & (idx < vcnt),
                              p.slots[victim], -1)
        new_victim = jnp.where(ok, jnp.roll(remaining, -n_steal),
                               p.slots[victim])
        my = p.slots[lane]
        new_mine = jnp.where(ok, jnp.where(idx < n_steal, stolen,
                                           jnp.roll(my, n_steal)), my)
        slots = p.slots.at[victim].set(new_victim).at[lane].set(new_mine)
        count = (p.count.at[victim].add(jnp.where(ok, -n_steal, 0))
                 .at[lane].add(jnp.where(ok, n_steal, 0)))
        return SlotPool(slots, count)

    pool = jax.lax.cond(pool.count[lane] >= n, lambda p: p, steal, pool)
    cnt = pool.count[lane]
    got = jnp.minimum(cnt, jnp.int32(n))
    idx = jnp.arange(n, dtype=jnp.int32)
    src = cnt - 1 - idx                        # stack top downward
    ids = jnp.where(idx < got,
                    pool.slots[lane, jnp.maximum(src, 0)], jnp.int32(-1))
    row = jnp.where(jnp.arange(cap, dtype=jnp.int32) >= cnt - got,
                    -1, pool.slots[lane])
    pool = SlotPool(pool.slots.at[lane].set(row),
                    pool.count.at[lane].add(-got))
    status = jnp.where(got == n, 0, 1).astype(jnp.int32)
    return pool, ids, got, status


def init_buffers(n_packets: int, packet_bytes: int) -> jax.Array:
    """Backing byte table for the functional pool — the in-graph mirror
    of :attr:`HostPacketPool.buffer_of` (one fixed-size pre-registered
    buffer per packet id)."""
    return jnp.zeros((n_packets, packet_bytes), jnp.uint8)


def pool_get_copy_n(pool: SlotPool, buf: jax.Array, lane, payload,
                    steal_seed) -> tuple[SlotPool, jax.Array, jax.Array,
                                         jax.Array, jax.Array]:
    """Fused allocate-and-stage (DESIGN.md §13): one dispatch pops a
    burst of packet slots AND scatters the burst's payload bytes into
    the pool's backing buffers — the doorbell's stage-copy without a
    host round-trip between "get packets" and "write payloads".

    ``payload`` is ``(n, row_bytes)`` uint8 (one packed wire image, e.g.
    from the doorbell stage-copy kernel); row ``i`` lands in
    ``buf[ids[i]]`` (zero-padded to the packet width).  On a short grab
    only the first ``got`` rows are written — the unallocated tail
    touches nothing, mirroring the host pool's prefix-accept split.
    Returns ``(pool', buf', ids, got, status)`` with the same id/status
    contract as :func:`pool_get_n`.
    """
    n, row_bytes = payload.shape
    n_packets, packet_bytes = buf.shape
    if row_bytes > packet_bytes:
        raise ValueError(f"pool_get_copy_n: payload rows of {row_bytes} "
                         f"bytes exceed packet_bytes={packet_bytes}")
    pool, ids, got, status = pool_get_n(pool, lane, n, steal_seed)
    rows = payload.astype(jnp.uint8)
    if row_bytes < packet_bytes:
        rows = jnp.pad(rows, ((0, 0), (0, packet_bytes - row_bytes)))
    # unallocated rows (id == -1) scatter out of bounds and are dropped
    idx = jnp.where(ids >= 0, ids, jnp.int32(n_packets))
    buf = buf.at[idx].set(rows, mode="drop")
    return pool, buf, ids, got, status


def pool_put(pool: SlotPool, lane, packet_id) -> tuple[SlotPool, jax.Array]:
    """Functional ``put``: push to stack top. Returns (pool', status)."""
    lane = jnp.asarray(lane, jnp.int32)
    cnt = pool.count[lane]
    cap = pool.slots.shape[1]
    ok = cnt < cap
    slots = pool.slots.at[lane, jnp.minimum(cnt, cap - 1)].set(
        jnp.where(ok, jnp.asarray(packet_id, jnp.int32),
                  pool.slots[lane, jnp.minimum(cnt, cap - 1)]))
    count = pool.count.at[lane].add(jnp.where(ok, 1, 0))
    return SlotPool(slots, count), jnp.where(ok, 0, 1).astype(jnp.int32)


def free_count(pool: SlotPool) -> jax.Array:
    return jnp.sum(pool.count)
