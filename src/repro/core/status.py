"""LCI status objects — the ternary ``done/posted/retry`` return protocol.

The paper (§3.2.5) defines four categories for every posting operation:

* ``done``   — completed immediately; completion objects will NOT be signaled.
* ``posted`` — accepted; completion objects will be signaled later.
* ``retry``  — temporary resource unavailability; caller should resubmit
  (or do something useful first: aggregate, poll other queues, ...).
* fatal     — raised as an exception (we mirror that: Python exceptions).

Compared to MPI's binary success/failure this surfaces back-pressure to the
client.  In LCI-X the same protocol governs trace-time posting (e.g. a send
with no matching recv yet -> ``posted``; a matched pair -> ``done`` with the
emitted value) and in-graph functional resources (packet pool exhaustion ->
``retry`` encoded as a status code in a traced int32).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional


class ErrorKind(enum.IntEnum):
    """Top-level status category (paper §3.2.5)."""

    DONE = 0
    POSTED = 1
    RETRY = 2
    ERR = 3                    # terminal failure (chaos plane, DESIGN.md §16)


class ErrorCode(enum.IntEnum):
    """Fine-grained codes within a category — "each category includes
    multiple error codes to deliver more information"."""

    # done
    DONE_OK = 0
    DONE_INLINE = 1            # const-folded / immediately completed comm
    # posted
    POSTED_OK = 10
    POSTED_UNMATCHED = 11      # send/recv inserted into matching engine
    POSTED_BACKLOG = 12        # moved to backlog queue
    # retry
    RETRY_NOPACKET = 20        # packet pool exhausted
    RETRY_NOSLOT = 21          # capacity slot unavailable (MoE / KV page)
    RETRY_LOCKED = 22          # try-lock analogue: resource busy
    RETRY_BACKLOG_FULL = 23
    RETRY_QUEUE_FULL = 24      # completion queue ring full
    # err — terminal: the op will never complete; comps ARE signaled
    # (exactly once) with the error status so callers never hang
    ERR_TIMEOUT = 30           # post deadline / retry budget exhausted
    ERR_PEER_DEAD = 31         # peer rank declared dead (heartbeat/chaos)


class FatalError(RuntimeError):
    """Paper: 'fatal errors are reported through C++ exceptions'."""


@dataclasses.dataclass(slots=True)
class Status:
    """The ``status_t`` object returned by posting/checking operations.

    When ``kind == DONE`` the payload fields (``value``/``buffer``, ``rank``,
    ``tag``) carry valid information about the completed operation.

    Slotted: statuses are the highest-volume objects on the data plane
    (two per eager message), so the ~20% ctor/footprint win matters.
    """

    kind: ErrorKind
    code: ErrorCode = ErrorCode.DONE_OK
    value: Any = None          # delivered payload (traced array or pytree)
    rank: Optional[int] = None
    tag: Optional[int] = None
    user_context: Any = None

    # -- predicates mirroring the paper's is_done / is_posted / is_retry ----
    def is_done(self) -> bool:
        return self.kind == ErrorKind.DONE

    def is_posted(self) -> bool:
        return self.kind == ErrorKind.POSTED

    def is_retry(self) -> bool:
        return self.kind == ErrorKind.RETRY

    def is_err(self) -> bool:
        return self.kind == ErrorKind.ERR

    def get_buffer(self):
        if not self.is_done():
            raise FatalError("status payload only valid when done")
        return self.value


def done(value: Any = None, *, code: ErrorCode = ErrorCode.DONE_OK,
         rank: int | None = None, tag: int | None = None) -> Status:
    return Status(ErrorKind.DONE, code, value=value, rank=rank, tag=tag)


def posted(*, code: ErrorCode = ErrorCode.POSTED_OK, ctx: Any = None) -> Status:
    return Status(ErrorKind.POSTED, code, user_context=ctx)


def retry(code: ErrorCode = ErrorCode.RETRY_LOCKED) -> Status:
    return Status(ErrorKind.RETRY, code)


def err(code: ErrorCode = ErrorCode.ERR_TIMEOUT, *,
        rank: int | None = None, tag: int | None = None,
        ctx: Any = None) -> Status:
    """Terminal failure status — signaled to comps exactly once in place
    of the ``done`` the op would have delivered (DESIGN.md §16)."""
    return Status(ErrorKind.ERR, code, rank=rank, tag=tag, user_context=ctx)


# Integer encodings for *in-graph* (traced) status values. Functional
# resources (packet pool, completion queue, ...) return an int32 status lane
# so that jitted code can branch on it with lax.cond / jnp.where.
IN_GRAPH_DONE = 0
IN_GRAPH_RETRY = 1
