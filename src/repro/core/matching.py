"""Matching engine (paper §4.1.3) — hash-bucket send/recv matching.

The engine exposes two methods, exactly as in the paper:

* ``make_key(rank, tag, policy)`` — build the match key.  ``matching_policy``
  (§3.3.2) selects which fields participate: ``rank_tag`` (default),
  ``rank_only``, ``tag_only``, or a user ``make_key`` function.
* ``insert(key, kind, value)`` — insert a send or receive; returns the
  matched value of the complementary kind if present, else stores the entry.

Two implementations live here:

1. :class:`HostMatchingEngine` — a Python dict-of-deques used at trace
   time (matching program-builder sends with recvs before emitting ppermute),
   by the serving router, and — since the concurrency subsystem landed —
   by concurrent progress workers.  The paper's per-bucket spinlock is
   real here: insertions take a fine-grained bucket lock (keys hash onto a
   fixed stripe of :class:`~repro.core.concurrency.TryLock`\\ s, so two
   inserts on different buckets never contend) and the whole
   check-complement/append step is atomic per bucket, which is what makes
   insert linearizable.
2. Functional jnp engine (:func:`init_table`, :func:`insert_batch`) — a
   fixed-capacity hash table living inside jitted programs; used by the MoE
   dispatch path (token -> expert matching with capacity) and exercised
   directly by the Fig-5 resource benchmark and hypothesis tests.

The paper's relaxed semantics (out-of-order delivery, restricted wildcard)
are what make the hash-table design legal; we adopt the same semantics and
the same default bucket count (65536).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Any, Callable, Hashable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import attrs as _attrs
from .concurrency.atomics import AtomicCounter
from .concurrency.locks import TryLock, aggregate_lock_stats
from .telemetry import NULL_TELEMETRY


class MatchKind(enum.IntEnum):
    SEND = 1
    RECV = 2

    @property
    def complement(self) -> "MatchKind":
        return MatchKind.RECV if self is MatchKind.SEND else MatchKind.SEND


class MatchingPolicy(enum.Enum):
    RANK_TAG = "rank_tag"    # default: match on (engine, source rank, tag)
    RANK_ONLY = "rank_only"  # wildcard tag
    TAG_ONLY = "tag_only"    # wildcard rank


def make_key(rank: int, tag: int,
             policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
             custom: Optional[Callable[[int, int], Hashable]] = None
             ) -> Hashable:
    """Build the insertion key (paper: 'the matching_policy will instruct the
    matching engine on how to make the insertion key based on rank and tag';
    users can also supply their own make_key)."""
    if custom is not None:
        return custom(rank, tag)
    if policy == MatchingPolicy.RANK_TAG:
        return (rank, tag)
    if policy == MatchingPolicy.RANK_ONLY:
        return (rank, None)
    return (None, tag)


class HostMatchingEngine(_attrs.AttrResource):
    """Trace-time / host-side matching engine, insert-linearizable.

    Buckets are materialized lazily (a Python dict is already a hash table);
    each bucket holds FIFO queues per kind, mirroring the paper's
    list-of-queues buckets.  ``insert`` returns the matched value or None.

    Lock granularity (DESIGN.md §10): keys hash onto ``n_locks`` bucket
    stripes; an insert spin-acquires its stripe's :class:`TryLock` (insert
    cannot fail, so the blocking fallback applies) and performs the
    check-complement / pop-or-append step atomically.  Two inserts whose
    keys land on different stripes proceed in parallel; two on the same
    key serialize, which is exactly the linearizability a send/recv match
    needs — one of them matches the other, never both or neither.
    """

    def __init__(self, n_buckets: int = 65536, n_locks: int = 64,
                 resolved=None, tele=None):
        self.n_buckets = n_buckets
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._buckets: dict[Hashable, dict[MatchKind, collections.deque]] = {}
        self.locks = [TryLock(name=f"match/bucket{i}")
                      for i in range(n_locks)]
        self._inserts = AtomicCounter()
        self._matches = AtomicCounter()
        self._fast_matches = AtomicCounter()
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"matching_buckets": n_buckets, "matching_locks": n_locks}))
        self._export_attr("inserts", lambda: self.inserts)
        self._export_attr("matches", lambda: self.matches)
        self._export_attr("fast_matches", lambda: self.fast_matches)
        self._export_attr("contention",
                          lambda: aggregate_lock_stats(self.locks))
        self._export_attr("telemetry", self._telemetry_block)

    @property
    def inserts(self) -> int:
        return self._inserts.load()

    @property
    def matches(self) -> int:
        return self._matches.load()

    @property
    def fast_matches(self) -> int:
        """Matches taken through the lock-free :meth:`match_now` probe."""
        return self._fast_matches.load()

    def _lock_of(self, key: Hashable) -> TryLock:
        return self.locks[hash(key) % len(self.locks)]

    def match_now(self, key: Hashable, kind: MatchKind):
        """Probe-before-lock fast path (the eager delivery hot case): pop
        a complementary entry *if one is already posted* — without ever
        taking the bucket lock — and NEVER store.

        The probe is a plain dict read; the pop is a single
        ``deque.popleft`` (GIL-atomic), so two concurrent fast-path
        deliveries can never double-match one recv, and a concurrent
        locked ``insert`` can never be dropped: ``insert`` re-checks the
        complement under the lock with the same atomic pop.  Returns the
        matched value, or ``None`` when no complement is posted — in
        which case the caller falls back to the locked :meth:`insert`
        (which stores into the unexpected queue)."""
        tele = self.tele
        if tele.timers_on:
            with tele.span("match.now"):
                return self._match_now_probe(key, kind)
        return self._match_now_probe(key, kind)

    def _match_now_probe(self, key: Hashable, kind: MatchKind):
        bucket = self._buckets.get(key)
        if bucket is None:
            return None
        try:
            value = bucket[kind.complement].popleft()
        except IndexError:
            return None
        self._matches.fetch_add(1)
        self._fast_matches.fetch_add(1)
        return value

    def match_now_n(self, key: Hashable, kind: MatchKind, n: int) -> list:
        """Burst probe for ONE key (a fused doorbell of uniform match
        keys): pop up to ``n`` pre-posted complements with a single
        bucket lookup and NEVER store.  Each pop is the same GIL-atomic
        ``popleft`` as :meth:`match_now`, so racing fast-path deliveries
        still never double-match one entry.  Returns the matched values
        in FIFO order (possibly fewer than ``n``, possibly empty) — the
        caller falls back to the locked :meth:`insert` per missing row."""
        bucket = self._buckets.get(key)
        if bucket is None:
            return []
        dq = bucket[kind.complement]
        out: list = []
        try:
            for _ in range(n):
                out.append(dq.popleft())
        except IndexError:
            pass
        if out:
            self._matches.fetch_add(len(out))
            self._fast_matches.fetch_add(len(out))
        return out

    def match_now_burst(self, keys: Sequence[Hashable], kind: MatchKind
                        ) -> list:
        """Vectorized probe for a whole burst's match keys (paper §4.3 at
        the matching engine): one pass groups the keys, then each unique
        key pays a single bucket lookup (:meth:`match_now_n`) for all its
        rows — duplicate keys in one doorbell cost one probe instead of
        K.  Returns values aligned with ``keys``; ``None`` rows had no
        pre-posted complement and fall back to the per-bucket locked
        path."""
        out: list = [None] * len(keys)
        if not self._buckets:
            return out
        groups: dict = {}
        for i, k in enumerate(keys):
            g = groups.get(k)
            if g is None:
                groups[k] = [i]
            else:
                g.append(i)
        for k, idxs in groups.items():
            for i, v in zip(idxs, self.match_now_n(k, kind, len(idxs))):
                out[i] = v
        return out

    def insert(self, key: Hashable, kind: MatchKind, value: Any):
        tele = self.tele
        if tele.timers_on:
            with tele.span("match.insert"):
                return self._insert_locked(key, kind, value)
        return self._insert_locked(key, kind, value)

    def _insert_locked(self, key: Hashable, kind: MatchKind, value: Any):
        self._inserts.fetch_add(1)
        with self._lock_of(key):
            bucket = self._buckets.setdefault(
                key, {MatchKind.SEND: collections.deque(),
                      MatchKind.RECV: collections.deque()})
            # pop-with-except rather than check-then-pop: a lock-free
            # match_now() racing this insert may drain the last
            # complement between a truthiness check and the popleft
            try:
                matched = bucket[kind.complement].popleft()
            except IndexError:
                bucket[kind].append(value)
                return None
            self._matches.fetch_add(1)
            return matched

    def remove(self, key: Hashable, kind: MatchKind, value: Any) -> bool:
        """Withdraw a previously inserted entry (identity match) — the
        recv-deadline expiry path (DESIGN.md §16).  Returns True when the
        entry was still queued and is now gone; False means it already
        matched (or was never inserted), so the caller must NOT fail the
        op — its completion is coming through the normal path."""
        with self._lock_of(key):
            bucket = self._buckets.get(key)
            if bucket is None:
                return False
            dq = bucket[kind]
            for v in dq:
                if v is value:
                    dq.remove(v)
                    return True
            return False

    def extract_recvs_for_rank(self, rank: int) -> list:
        """Withdraw every queued RECV whose key names ``rank`` — the
        dead-peer sweep (DESIGN.md §16).  Wildcard-rank keys stay: a
        TAG_ONLY recv can still match a living sender.  Returns the
        extracted values."""
        out: list = []
        for key in list(self._buckets.keys()):
            if not (isinstance(key, tuple) and key and key[0] == rank):
                continue
            with self._lock_of(key):
                bucket = self._buckets.get(key)
                if bucket is None:
                    continue
                dq = bucket[MatchKind.RECV]
                while dq:
                    try:
                        out.append(dq.popleft())
                    except IndexError:
                        break
        return out

    def pending(self) -> int:
        # snapshot the bucket list in one C-level call (GIL-atomic) so a
        # concurrent insert growing the dict cannot break the iteration
        return sum(len(q) for b in list(self._buckets.values())
                   for q in b.values())

    def lock_stats(self) -> list[dict]:
        """Per-bucket-stripe lock telemetry."""
        return [lk.stats() for lk in self.locks]

    def telemetry_counters(self) -> dict:
        """This engine's legacy counters for the unified snapshot (the
        owning runtime attaches this under the ``matching.`` prefix)."""
        locks = aggregate_lock_stats(self.locks)
        return {"inserts": self.inserts, "matches": self.matches,
                "fast_matches": self.fast_matches,
                "lock_contentions": locks["contentions"]}

    def _telemetry_block(self) -> dict:
        return {"level": self.tele.level,
                "counters": {f"matching.{k}": v
                             for k, v in self.telemetry_counters().items()}}


# ---------------------------------------------------------------------------
# Functional (in-graph) engine.
#
# Fixed geometry: ``n_buckets`` x ``bucket_cap`` slots. State arrays:
#   keys  (n_buckets, bucket_cap) int32   -- 0 == empty
#   kinds (n_buckets, bucket_cap) int32   -- MatchKind or 0
#   vals  (n_buckets, bucket_cap) int32   -- payload index (e.g. packet slot)
#
# The paper's low-load fast path ("fixed-size arrays instead of linked lists
# ... an insertion with a single cache miss") is structural here: every slot
# probe is a vectorized compare over one bucket row.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchTable:
    keys: jax.Array
    kinds: jax.Array
    vals: jax.Array

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.keys, self.kinds, self.vals), None


jax.tree_util.register_pytree_node(
    MatchTable,
    lambda t: ((t.keys, t.kinds, t.vals), None),
    lambda _, c: MatchTable(*c))


def init_table(n_buckets: int, bucket_cap: int) -> MatchTable:
    shape = (n_buckets, bucket_cap)
    return MatchTable(
        keys=jnp.zeros(shape, jnp.int32),
        kinds=jnp.zeros(shape, jnp.int32),
        vals=jnp.full(shape, -1, jnp.int32),
    )


def _hash_key(key: jax.Array, n_buckets: int) -> jax.Array:
    """Cheap integer hash (Knuth multiplicative) -> bucket index."""
    h = (key.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def encode_key(rank, tag, policy: MatchingPolicy = MatchingPolicy.RANK_TAG):
    """Pack (rank, tag) into one nonzero int32 key under the policy.

    Layout: bit 30 = nonzero marker, bits 16..29 = rank (14 bits),
    bits 0..15 = tag.  (Bit 31 would overflow int32.)"""
    rank = jnp.asarray(rank, jnp.int32)
    tag = jnp.asarray(tag, jnp.int32)
    if policy == MatchingPolicy.RANK_ONLY:
        tag = jnp.zeros_like(tag)
    elif policy == MatchingPolicy.TAG_ONLY:
        rank = jnp.zeros_like(rank)
    return ((rank & 0x3FFF) << 16) | (tag & 0xFFFF) | (1 << 30)


def insert(table: MatchTable, key: jax.Array, kind: int, val: jax.Array):
    """Insert one entry; returns (table', matched_val, status).

    matched_val == -1 when no complementary entry existed (entry stored,
    status=posted->0 stored / 1 matched); status==2 => bucket full (retry).
    """
    n_buckets, cap = table.keys.shape
    b = _hash_key(key, n_buckets)
    row_keys = table.keys[b]
    row_kinds = table.kinds[b]
    comp = jnp.int32(MatchKind(kind).complement)

    is_match = (row_keys == key) & (row_kinds == comp)
    any_match = jnp.any(is_match)
    match_slot = jnp.argmax(is_match)          # first matching slot
    matched_val = jnp.where(any_match, table.vals[b, match_slot], -1)

    is_empty = row_kinds == 0
    any_empty = jnp.any(is_empty)
    empty_slot = jnp.argmax(is_empty)

    # On match: clear the matched slot. On store: fill the empty slot.
    slot = jnp.where(any_match, match_slot, empty_slot)
    new_key = jnp.where(any_match, 0, key)
    new_kind = jnp.where(any_match, 0, jnp.int32(kind))
    new_val = jnp.where(any_match, -1, val)
    can_write = any_match | any_empty

    def write(arr, v):
        return jax.lax.cond(
            can_write,
            lambda a: a.at[b, slot].set(v.astype(a.dtype)),
            lambda a: a, arr)

    table = MatchTable(write(table.keys, new_key),
                       write(table.kinds, new_kind),
                       write(table.vals, new_val))
    status = jnp.where(any_match, 1, jnp.where(any_empty, 0, 2))
    return table, matched_val, status


def insert_batch(table: MatchTable, keys, kinds, vals):
    """Sequential batch insert via scan (keeps matching semantics exact)."""

    def step(tab, kkv):
        k, kind, v = kkv
        tab, m, s = _insert_dyn(tab, k, kind, v)
        return tab, (m, s)

    table, (matched, status) = jax.lax.scan(
        step, table, (keys, kinds.astype(jnp.int32), vals))
    return table, matched, status


def _insert_dyn(table: MatchTable, key, kind, val):
    """insert() with traced ``kind`` (scan-compatible)."""
    n_buckets, _ = table.keys.shape
    b = _hash_key(key, n_buckets)
    row_keys = table.keys[b]
    row_kinds = table.kinds[b]
    comp = jnp.where(kind == jnp.int32(MatchKind.SEND),
                     jnp.int32(MatchKind.RECV), jnp.int32(MatchKind.SEND))

    is_match = (row_keys == key) & (row_kinds == comp)
    any_match = jnp.any(is_match)
    match_slot = jnp.argmax(is_match)
    matched_val = jnp.where(any_match, table.vals[b, match_slot], -1)

    is_empty = row_kinds == 0
    any_empty = jnp.any(is_empty)
    empty_slot = jnp.argmax(is_empty)

    slot = jnp.where(any_match, match_slot, empty_slot)
    new_key = jnp.where(any_match, 0, key)
    new_kind = jnp.where(any_match, 0, kind)
    new_val = jnp.where(any_match, -1, val)
    can_write = any_match | any_empty

    def sel(arr, v):
        old = arr[b, slot]
        return arr.at[b, slot].set(jnp.where(can_write, v.astype(arr.dtype),
                                             old))

    table = MatchTable(sel(table.keys, new_key),
                       sel(table.kinds, new_kind),
                       sel(table.vals, new_val))
    status = jnp.where(any_match, 1, jnp.where(any_empty, 0, 2))
    return table, matched_val, status


def probe(table: MatchTable, key: jax.Array, kind: int):
    """Functional ``match_now``: pop a complementary entry if one is
    already stored — NEVER store.  Returns ``(table', matched_val,
    hit)``; ``matched_val == -1`` and ``hit == False`` when no
    complement is present (the caller falls back to :func:`insert`)."""
    n_buckets, _ = table.keys.shape
    b = _hash_key(key, n_buckets)
    row_keys = table.keys[b]
    row_kinds = table.kinds[b]
    comp = jnp.int32(MatchKind(kind).complement)

    is_match = (row_keys == key) & (row_kinds == comp)
    any_match = jnp.any(is_match)
    slot = jnp.argmax(is_match)
    matched_val = jnp.where(any_match, table.vals[b, slot], -1)

    def clear(arr, empty):
        old = arr[b, slot]
        return arr.at[b, slot].set(jnp.where(any_match,
                                             jnp.asarray(empty, arr.dtype),
                                             old))

    table = MatchTable(clear(table.keys, 0), clear(table.kinds, 0),
                       clear(table.vals, -1))
    return table, matched_val, any_match


def probe_batch(table: MatchTable, keys, kind: int):
    """Vectorized burst probe — the fused doorbell's one hashed-array
    pass: every key is hashed and its bucket row compared in a single
    vectorized gather, producing a per-key candidate mask; the actual
    pops then resolve sequentially (scan), because duplicate keys in one
    burst must each pop a *distinct* pre-posted entry — the same
    exactness argument as :func:`insert_batch`.  Returns ``(table',
    matched_vals, hits)`` aligned with ``keys``."""
    n_buckets, _ = table.keys.shape
    keys = jnp.asarray(keys, jnp.int32)
    comp = jnp.int32(MatchKind(kind).complement)
    # the one hashed-array pass: (k,) bucket indices, (k, cap) gathered
    # rows, one vectorized candidate mask over the whole burst
    b = _hash_key(keys, n_buckets)
    candidates = jnp.any((table.keys[b] == keys[:, None])
                         & (table.kinds[b] == comp), axis=1)

    def step(tab, kc):
        key, cand = kc

        def hit(t):
            return probe(t, key, int(kind))

        def miss(t):
            return t, jnp.int32(-1), jnp.asarray(False)

        tab, val, ok = jax.lax.cond(cand, hit, miss, tab)
        return tab, (val, ok)

    table, (vals, hits) = jax.lax.scan(step, table, (keys, candidates))
    return table, vals, hits


def pending_count(table: MatchTable) -> jax.Array:
    return jnp.sum(table.kinds != 0)
