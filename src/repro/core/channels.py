"""Channels & devices — LCI's replicable communication resources, on TPU.

Paper (§3.2.3): "a device encapsulat[es] a complete set of low-level network
resources and LCI ensures threads operating on different devices will not
interfere with each other."  Replicating devices is how LCI's dedicated-
resource mode beats the shared-resource mode.

On a TPU there is no NIC handle to replicate; the serialization a device
removes lives in the *collective schedule*.  LCI-X therefore defines:

* :class:`Channel` — one independent chunk-stream of ICI traffic.  A ring
  collective over ``n`` channels splits its payload into ``n`` interleaved
  streams; on the torus, two channels map naturally onto the two link
  directions (bidirectional rings), and further channels become concurrent
  chunk slots XLA can schedule against compute
  (``collective-permute-start``/``done`` pairs in HLO).
* :class:`Device` — a full replicable resource set: channels + a packet-pool
  lane + a completion queue + a backlog queue.  ``Runtime.alloc_device``
  hands these out; the host-side microbenchmarks replicate them per lane
  exactly like the paper replicates devices per thread.

The *contention-free guarantee* (paper §4.2.3: no interference between a
worker posting and a progress thread) maps to: operations on different
devices touch disjoint functional state, so the jit dataflow graph has no
edges between them — structural, checkable, and checked in tests.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Optional

from . import attrs as _attrs
from .backlog import BacklogQueue
from .completion import CompletionQueue
from .concurrency.atomics import AtomicCounter
from .concurrency.locks import TryLock
from .modes import CommConfig, CommMode
from .telemetry import NULL_TELEMETRY

#: attrs a device resolves at alloc time (n_channels may be overridden
#: per device; 0-capacities mean unbounded)
DEVICE_ATTRS = ("n_channels", "backlog_capacity", "cq_capacity")

_device_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class Channel:
    """One independent chunk-stream. ``direction`` ∈ {+1, -1} picks the ring
    orientation on the ICI torus axis; interleaved chunk index picks the
    payload slice."""

    cid: int
    direction: int
    chunk_index: int
    n_chunks: int


def make_channels(n: int) -> tuple[Channel, ...]:
    """n channels: alternate ring directions, interleave chunk slots."""
    chans = []
    for i in range(n):
        chans.append(Channel(cid=i,
                             direction=+1 if i % 2 == 0 else -1,
                             chunk_index=i,
                             n_chunks=n))
    return tuple(chans)


class Device(_attrs.AttrResource):
    """A replicable set of communication resources (paper: LCI device)."""

    def __init__(self, config: CommConfig, lane: int,
                 cq: Optional[CompletionQueue] = None,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 tele=None):
        self.did = next(_device_ids)
        self.lane = lane                       # packet-pool lane this device owns
        self.config = config
        if resolved is None:
            resolved = _attrs.resolved_from_values(
                {"n_channels": config.resolved_channels(),
                 "backlog_capacity": 0, "cq_capacity": 0})
        # an explicit per-device n_channels override beats the
        # config-derived width; otherwise the mode logic decides (BSP and
        # LCI_SHARED collapse to one channel regardless of the knob) —
        # and the stored resolution must agree with the width the device
        # actually runs with, so re-merge when the mode collapsed it
        n_chan = (resolved["n_channels"]
                  if resolved.source("n_channels") == "resource"
                  else config.resolved_channels())
        if resolved["n_channels"] != n_chan:
            resolved = resolved.merged(_attrs.ResolvedAttrs(
                {"n_channels": n_chan},
                {"n_channels": resolved.source("n_channels")}))
        self._init_attrs(resolved)
        self.channels = make_channels(n_chan)
        self.cq = cq or CompletionQueue(resolved["cq_capacity"] or None)
        self.backlog = BacklogQueue(resolved["backlog_capacity"] or None)
        self._export_attr("lane", lambda: self.lane)
        self._export_attr("width", lambda: len(self.channels))
        self._export_attr("posts", lambda: self.posts)
        self._export_attr("pushes", lambda: self.pushes)
        self._export_attr("progresses", lambda: self.progresses)
        self._export_attr("progress_lock_stats",
                          lambda: self.progress_lock.stats())
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._export_attr("telemetry", self._telemetry_block)
        self.index = 0                         # position in the owner's device list
        self.pending_tx = collections.deque()  # ops awaiting source completion
        # per-device progress try-lock (paper §4.2.3): any number of
        # threads may call progress; the holder runs the reaction chain,
        # a loser "moves on".  Reentrant: a completion callback fired
        # inside a pass may legally drive progress on its own device.
        self.progress_lock = TryLock(name=f"device{self.did}/progress",
                                     reentrant=True)
        # telemetry (paper's "progress" counters) — atomic: posts/pushes
        # are bumped by arbitrary poster threads, progresses by whichever
        # thread holds the progress lock
        self._posts = AtomicCounter()
        self._pushes = AtomicCounter()
        self._progresses = AtomicCounter()

    # counters read as plain ints; writers use count_*()
    @property
    def posts(self) -> int:
        return self._posts.load()

    @property
    def pushes(self) -> int:
        return self._pushes.load()

    @property
    def progresses(self) -> int:
        return self._progresses.load()

    def count_post(self, n: int = 1) -> None:
        self._posts.fetch_add(n)

    def count_push(self, n: int = 1) -> None:
        self._pushes.fetch_add(n)

    def count_progress(self) -> None:
        self._progresses.fetch_add(1)

    def _telemetry_block(self) -> dict:
        """This device's contribution to the unified snapshot
        (DESIGN.md §15): its legacy counters under dotted names."""
        ls = self.progress_lock.stats()
        return {"level": self.tele.level,
                "counters": {"device.posts": self.posts,
                             "device.pushes": self.pushes,
                             "device.progresses": self.progresses,
                             "device.lock_acquisitions": ls["acquisitions"],
                             "device.lock_contentions": ls["contentions"]}}

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def __repr__(self) -> str:
        return (f"Device(id={self.did}, lane={self.lane}, "
                f"channels={self.n_channels}, mode={self.config.mode.value})")
