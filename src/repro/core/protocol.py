"""Communication protocols (paper §4.3) — inject / buffer-copy / zero-copy.

"For the send-receive and active message operations, depending on the
message size, LCI adopts three different communication protocols: inject,
buffer-copy, and zero-copy.  For put/get operations, LCI directly
translates them into the corresponding low-level network operations."

* ``INJECT``    — tiny payloads ride the descriptor itself (no packet, no
  handshake); completes immediately at the source (``done``).
* ``BUFCOPY``   — the payload is copied into a fixed-size pre-registered
  packet (pool ``get``; ``retry`` on exhaustion), sent eagerly, and the
  packet returns to the pool on source completion.
* ``ZEROCOPY``  — rendezvous: an RTS descriptor travels first; the target
  matches it (recv posted / AM buffer allocated) and replies CTS; the
  payload then moves directly between registered buffers (no copy).

In LCI-X's in-graph world the same trichotomy appears as: *inject* =
aggregate small tensors into one fused collective; *buffer-copy* = staging
through capacity slots (MoE, paged KV); *zero-copy* = direct chunked
ppermute rings (:mod:`repro.core.collectives`).  The host runtime uses this
module literally.
"""
from __future__ import annotations

import dataclasses
import enum

from .modes import CommConfig


class Protocol(enum.Enum):
    INJECT = "inject"
    BUFCOPY = "bufcopy"
    ZEROCOPY = "zerocopy"


def select_protocol(size_bytes: int, config: CommConfig) -> Protocol:
    """Size-driven protocol selection (thresholds live on CommConfig)."""
    if size_bytes <= config.inject_max_bytes:
        return Protocol.INJECT
    if size_bytes <= config.bufcopy_max_bytes:
        return Protocol.BUFCOPY
    return Protocol.ZEROCOPY


@dataclasses.dataclass
class ProtocolStats:
    """Telemetry: how many messages/bytes took each path (benchmarks read
    this to report the protocol mix per run)."""

    inject_msgs: int = 0
    inject_bytes: int = 0
    bufcopy_msgs: int = 0
    bufcopy_bytes: int = 0
    zerocopy_msgs: int = 0
    zerocopy_bytes: int = 0
    handshakes: int = 0          # RTS/CTS round trips
    retries: int = 0             # back-pressure events surfaced to clients

    def record(self, proto: Protocol, size: int) -> None:
        if proto == Protocol.INJECT:
            self.inject_msgs += 1
            self.inject_bytes += size
        elif proto == Protocol.BUFCOPY:
            self.bufcopy_msgs += 1
            self.bufcopy_bytes += size
        else:
            self.zerocopy_msgs += 1
            self.zerocopy_bytes += size

    def record_many(self, proto: Protocol, n_msgs: int, n_bytes: int) -> None:
        """Burst telemetry: one counter bump for a whole doorbell."""
        if proto == Protocol.INJECT:
            self.inject_msgs += n_msgs
            self.inject_bytes += n_bytes
        elif proto == Protocol.BUFCOPY:
            self.bufcopy_msgs += n_msgs
            self.bufcopy_bytes += n_bytes
        else:
            self.zerocopy_msgs += n_msgs
            self.zerocopy_bytes += n_bytes

    @property
    def total_msgs(self) -> int:
        return self.inject_msgs + self.bufcopy_msgs + self.zerocopy_msgs

    @property
    def total_bytes(self) -> int:
        return self.inject_bytes + self.bufcopy_bytes + self.zerocopy_bytes
