"""In-graph collectives — LCI-X's zero-copy protocol on the ICI torus.

This module is the heart of the TPU adaptation (DESIGN.md §2).  Every
function takes a ``CommConfig`` whose mode selects between:

* ``BSP``           — monolithic XLA collective, compute strictly after
  (paper's MPI/bulk-synchronous baseline);
* ``LCI_SHARED``    — ring decomposition on a single channel: per-step
  ``ppermute`` is asynchronous (``collective-permute-start/done``) so XLA
  can overlap the *next* transfer with the *current* compute chunk;
* ``LCI_DEDICATED`` — ring decomposition over dedicated channels: the two
  ICI link directions run counter-rotating rings concurrently, halving the
  number of serial ring steps (gather: distance-split; reduce: payload-
  split), on top of the same per-step overlap.

All functions must be called inside ``shard_map`` with ``axis_name`` bound.
Matmul accumulation is fp32 (``preferred_element_type``) regardless of the
payload dtype.  Ring loops are written so that *no wasted ppermute* is
emitted (first/last iterations peeled); the dry-run's collective-byte count
is therefore exact, and no collective sits under a ``lax.cond``.

Also here: the collective primitives the paper says LCI provides (§6
"dissemination-based barrier and tree-based broadcast/reduce") built on the
same ppermute substrate.

Correctness invariants (tested in tests/test_collectives.py against the BSP
mode and pure-jnp oracles):

* gather rings: the forward ring delivers sources ``idx-1 .. idx-sf``
  (``sf = ceil((P-1)/2)``), the backward ring ``idx+1 .. idx+sb``
  (``sb = P-1-sf``) — a partition of the non-self sources, each carried the
  short way round the torus.
* reduce rings: a contribution added at rank ``r`` on step ``i`` rides the
  +1 ring ``P-1-i`` more hops, so it must target ``dst = r + P-1-i``; on
  the −1 ring, ``dst = r + i + 1``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size

from .modes import CommConfig, CommMode

DEFAULT = CommConfig()


def _ring_perm(n: int, direction: int = +1):
    return [(i, (i + direction) % n) for i in range(n)]


def _update_at(buf: jax.Array, piece: jax.Array, axis: int, start
               ) -> jax.Array:
    starts = [jnp.int32(0)] * buf.ndim
    starts[axis] = jnp.asarray(start, jnp.int32)
    return lax.dynamic_update_slice(buf, piece.astype(buf.dtype),
                                    tuple(starts))


def _slice_at(src: jax.Array, axis: int, start, size: int) -> jax.Array:
    starts = [jnp.int32(0)] * src.ndim
    starts[axis] = jnp.asarray(start, jnp.int32)
    sizes = list(src.shape)
    sizes[axis] = size
    return lax.dynamic_slice(src, tuple(starts), tuple(sizes))


# ---------------------------------------------------------------------------
# all-gather (zero-copy ring)
# ---------------------------------------------------------------------------

def all_gather(x: jax.Array, axis_name: str,
               config: CommConfig = DEFAULT, *, axis: int = 0) -> jax.Array:
    """All-gather ``x`` (sharded on ``axis``) across ``axis_name``."""
    if config.mode == CommMode.BSP:
        return lax.all_gather(x, axis_name, axis=axis, tiled=True)
    return _ring_all_gather(
        x, axis_name, axis=axis,
        bidirectional=config.mode == CommMode.LCI_DEDICATED)


def _ring_all_gather(x: jax.Array, axis_name: str, *, axis: int,
                     bidirectional: bool) -> jax.Array:
    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    shard = x.shape[axis]
    out_shape = x.shape[:axis] + (shard * p,) + x.shape[axis + 1:]
    out = jnp.zeros(out_shape, x.dtype)
    if p == 1:
        return _update_at(out, x, axis, 0)

    sf = (p - 1 + 1) // 2          # forward hops = ceil((P-1)/2)
    sb = (p - 1) - sf              # backward hops

    # Rings are unrolled (p is static inside shard_map): every iteration is
    # visible to XLA's async scheduler (collective-permute-start/done pairs
    # overlap with the dus/compute of the previous arrival), and the whole
    # construct is reverse-mode differentiable (fori_loop is not).
    if not bidirectional or sb == 0:
        cur = x
        for i in range(p):
            out = _update_at(out, cur, axis, ((idx - i) % p) * shard)
            if i < p - 1:
                cur = lax.ppermute(cur, axis_name, _ring_perm(p, +1))
        return out

    # bidirectional (distance-split): exactly sf forward + sb backward hops.
    out = _update_at(out, x, axis, idx * shard)              # self
    cf, cb = x, x
    for j in range(1, sf + 1):
        cf = lax.ppermute(cf, axis_name, _ring_perm(p, +1))
        out = _update_at(out, cf, axis, ((idx - j) % p) * shard)
        if j <= sb:
            cb = lax.ppermute(cb, axis_name, _ring_perm(p, -1))
            out = _update_at(out, cb, axis, ((idx + j) % p) * shard)
    return out


# ---------------------------------------------------------------------------
# all-gather matmul:  Y = allgather(X) @ W   (column-parallel TP with SP)
# ---------------------------------------------------------------------------

def all_gather_matmul(x: jax.Array, w: jax.Array, axis_name: str,
                      config: CommConfig = DEFAULT) -> jax.Array:
    """``x``: (m_shard, ..., k) sharded on dim 0 over ``axis_name``; ``w``:
    (k, n) local (replicated or column-shard).  Returns (m_shard*P, ..., n)
    — ``allgather(x, axis=0) @ w`` with the contraction on the last dim.

    LCI modes compute ``x_i @ w`` while the ring permutes ``x_{i+1}`` —
    the collective-matmul overlap schedule (completion-graph semantics:
    matmul_i depends only on shard_i's arrival, not on the whole gather).
    Rings are unrolled: differentiable, and every transfer is independently
    schedulable against the previous arrival's matmul.
    """
    if config.mode == CommMode.BSP:
        xg = lax.all_gather(x, axis_name, axis=0, tiled=True)
        return jnp.tensordot(xg, w, axes=1).astype(x.dtype)

    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m_shard = x.shape[0]
    out = jnp.zeros((m_shard * p,) + x.shape[1:-1] + (w.shape[1],), x.dtype)

    def mm(cur):
        return jax.lax.dot_general(
            cur, w, (((cur.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if p == 1:
        return _update_at(out, mm(x), 0, 0)

    sf = (p - 1 + 1) // 2
    sb = (p - 1) - sf

    if config.mode == CommMode.LCI_SHARED or sb == 0:
        cur = x
        for i in range(p):
            out = _update_at(out, mm(cur), 0, ((idx - i) % p) * m_shard)
            if i < p - 1:
                cur = lax.ppermute(cur, axis_name, _ring_perm(p, +1))
        return out

    # dedicated: counter-rotating rings, matmul per arrival
    out = _update_at(out, mm(x), 0, idx * m_shard)
    cf, cb = x, x
    for j in range(1, sf + 1):
        cf = lax.ppermute(cf, axis_name, _ring_perm(p, +1))
        out = _update_at(out, mm(cf), 0, ((idx - j) % p) * m_shard)
        if j <= sb:
            cb = lax.ppermute(cb, axis_name, _ring_perm(p, -1))
            out = _update_at(out, mm(cb), 0, ((idx + j) % p) * m_shard)
    return out


# ---------------------------------------------------------------------------
# matmul reduce-scatter:  Y = reduce_scatter(X @ W)  (row-parallel TP)
# ---------------------------------------------------------------------------

def matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis_name: str,
                          config: CommConfig = DEFAULT) -> jax.Array:
    """``x``: (m, k_shard), ``w``: (k_shard, n) sharded on k over
    ``axis_name``.  Returns the row-scattered sum: (m/P, n) on each rank.

    LCI modes ring-accumulate: each step computes one m-slice's partial
    product and adds it to the accumulator arriving from the neighbour —
    the transfer of step i overlaps the matmul of step i+1.  Dedicated mode
    splits the n (feature) axis over two counter-rotating rings.
    """
    p = axis_size(axis_name)
    m = x.shape[0]
    assert m % p == 0, f"matmul_reduce_scatter: m={m} not divisible by P={p}"
    m_shard = m // p

    if config.mode == CommMode.BSP:
        full = jnp.tensordot(x, w, axes=1)
        return lax.psum_scatter(full, axis_name, scatter_dimension=0,
                                tiled=True).astype(x.dtype)

    idx = lax.axis_index(axis_name)

    def one_ring(w_part: jax.Array, direction: int) -> jax.Array:
        def dst(i):
            if direction == +1:
                return (idx + p - 1 - i) % p
            return (idx + i + 1) % p

        def contrib(i):
            piece = _slice_at(x, 0, dst(i) * m_shard, m_shard)
            return jax.lax.dot_general(
                piece, w_part, (((piece.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        acc = contrib(0)
        wire = jnp.bfloat16 if config.wire_bf16 else None
        for i in range(1, p):
            if wire is not None:
                # bf16 on the wire, fp32 local accumulate (CommConfig knob)
                acc = lax.ppermute(acc.astype(wire), axis_name,
                                   _ring_perm(p, direction)
                                   ).astype(jnp.float32)
            else:
                acc = lax.ppermute(acc, axis_name,
                                   _ring_perm(p, direction))
            acc = acc + contrib(i)
        return acc

    n = w.shape[1]
    if config.mode == CommMode.LCI_DEDICATED and p > 1 and n % 2 == 0:
        lo = one_ring(w[:, :n // 2], +1)
        hi = one_ring(w[:, n // 2:], -1)
        return jnp.concatenate([lo, hi], axis=-1).astype(x.dtype)
    return one_ring(w, +1).astype(x.dtype)


# ---------------------------------------------------------------------------
# reduce-scatter / all-reduce on raw tensors (gradient sync path)
# ---------------------------------------------------------------------------

def reduce_scatter(x: jax.Array, axis_name: str,
                   config: CommConfig = DEFAULT, *, axis: int = 0
                   ) -> jax.Array:
    """Ring reduce-scatter of ``x`` along ``axis`` across ``axis_name``."""
    p = axis_size(axis_name)
    if config.mode == CommMode.BSP or x.shape[axis] % p != 0:
        return lax.psum_scatter(x, axis_name, scatter_dimension=axis,
                                tiled=True)
    idx = lax.axis_index(axis_name)
    shard = x.shape[axis] // p

    def one_ring(src: jax.Array, direction: int) -> jax.Array:
        def dst(i):
            if direction == +1:
                return (idx + p - 1 - i) % p
            return (idx + i + 1) % p

        def contrib(i):
            return _slice_at(src, axis, dst(i) * shard, shard
                             ).astype(jnp.float32)

        acc = contrib(0)
        wire = jnp.bfloat16 if config.wire_bf16 else None
        for i in range(1, p):
            if wire is not None:
                acc = lax.ppermute(acc.astype(wire), axis_name,
                                   _ring_perm(p, direction)
                                   ).astype(jnp.float32)
            else:
                acc = lax.ppermute(acc, axis_name,
                                   _ring_perm(p, direction))
            acc = acc + contrib(i)
        return acc.astype(x.dtype)

    feat = x.ndim - 1
    if (config.mode == CommMode.LCI_DEDICATED and p > 1
            and feat != axis and x.shape[feat] % 2 == 0):
        lo, hi = jnp.split(x, 2, axis=feat)
        return jnp.concatenate(
            [one_ring(lo, +1), one_ring(hi, -1)], axis=feat)
    return one_ring(x, +1)


def all_reduce(x: jax.Array, axis_name: str,
               config: CommConfig = DEFAULT) -> jax.Array:
    """All-reduce = ring reduce-scatter + ring all-gather in LCI modes, or a
    single psum in BSP.  Falls back to psum when the leading dim does not
    divide the axis size."""
    if (config.mode == CommMode.BSP or x.ndim == 0
            or x.shape[0] % axis_size(axis_name) != 0):
        return lax.psum(x, axis_name)
    scattered = reduce_scatter(x, axis_name, config, axis=0)
    return all_gather(scattered, axis_name, config, axis=0)


# ---------------------------------------------------------------------------
# all-to-all (MoE dispatch / combine)
# ---------------------------------------------------------------------------

def all_to_all(x: jax.Array, axis_name: str, *, split_axis: int,
               concat_axis: int, config: CommConfig = DEFAULT,
               tiled: bool = True) -> jax.Array:
    """Chunked all-to-all: LCI modes slice a non-participating dim into
    ``n_channels`` chunks issued as independent collectives (XLA overlaps
    them with the surrounding expert compute)."""
    n = config.resolved_channels()
    if config.mode == CommMode.BSP or n <= 1:
        return lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)
    feat_axis = x.ndim - 1
    if feat_axis in (split_axis, concat_axis) or x.shape[feat_axis] % n != 0:
        return lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)
    chunks = jnp.split(x, n, axis=feat_axis)
    outs = [lax.all_to_all(c, axis_name, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=tiled)
            for c in chunks]
    return jnp.concatenate(outs, axis=feat_axis)


# ---------------------------------------------------------------------------
# paper §6 collective primitives: dissemination barrier, tree bcast/reduce
# ---------------------------------------------------------------------------

def dissemination_barrier(axis_name: str) -> jax.Array:
    """Dissemination barrier: ceil(log2 P) rounds; returns a token that
    data-depends on every rank (so anything consuming it is ordered after
    the barrier).  Token value == P on every rank (checked in tests)."""
    p = axis_size(axis_name)
    token = jnp.ones((), jnp.int32)
    dist = 1
    while dist < p:
        perm = [(i, (i + dist) % p) for i in range(p)]
        token = token + lax.ppermute(token, axis_name, perm)
        dist *= 2
    return token


def tree_broadcast(x: jax.Array, axis_name: str, *, root: int = 0
                   ) -> jax.Array:
    """Binomial-tree broadcast from ``root`` via masked ppermute rounds."""
    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    rel = (idx - root) % p              # root-relative rank
    val = x
    have = rel == 0
    span = 1
    while span < p:
        # relative ranks [0, span) send to [span, 2*span)
        perm = [((i + root) % p, (i + span + root) % p)
                for i in range(span) if i + span < p]
        incoming = lax.ppermute(val, axis_name, perm)
        recv_now = (rel >= span) & (rel < 2 * span)
        val = jnp.where(recv_now & ~have, incoming, val)
        have = have | recv_now
        span *= 2
    return val


def tree_reduce(x: jax.Array, axis_name: str, *, root: int = 0) -> jax.Array:
    """Binomial-tree sum-reduce to ``root`` (other ranks return partials;
    callers wanting all-reduce should tree_broadcast afterwards)."""
    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    rel = (idx - root) % p
    val = x
    span = 1
    while span < p:
        # relative ranks with rel % 2span == span send to rel - span
        perm = [((i + root) % p, (i - span + root) % p)
                for i in range(p) if i % (2 * span) == span]
        incoming = lax.ppermute(val, axis_name, perm)
        is_recv = (rel % (2 * span) == 0) & (rel + span < p)
        val = jnp.where(is_recv, val + incoming, val)
        span *= 2
    return val
