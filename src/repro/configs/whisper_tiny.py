"""whisper-tiny [audio] — encoder-decoder backbone; conv frontend STUB.

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865 (padded to 51968)
[arXiv:2212.04356; unverified].  input_specs provides precomputed frame
embeddings (1500 frames = 30 s at 50 Hz post-conv).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    norm="layernorm", mlp="gelu", tie_embeddings=True,
    encoder_layers=4, n_audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, norm="layernorm", mlp="gelu",
    tie_embeddings=True, encoder_layers=2, n_audio_frames=16, tp_target=4,
)
