"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 ssm_state=128 vocab=50280 [arXiv:2405.21060; unverified]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    norm="rmsnorm", tie_embeddings=True,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    ssm_conv_kernel=4, ssm_groups=1,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=512, norm="rmsnorm", tie_embeddings=True,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8, tp_target=4,
)
