"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th.

100L (80 self + 20 gated cross) d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
Frontend STUB: input_specs provides precomputed patch embeddings.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    norm="rmsnorm", mlp="swiglu",
    cross_attn_every=5, n_image_tokens=1600,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-90b-smoke", family="vlm",
    n_layers=4, d_model=96, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=512, head_dim=24, norm="rmsnorm", mlp="swiglu",
    cross_attn_every=2, n_image_tokens=8, tp_target=4,
)
