"""moonshot-v1-16b-a3b [moe] — Moonlight: 64 experts top-6 + shared experts.

48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    norm="rmsnorm", mlp="swiglu",
    n_experts=64, top_k=6, shared_expert_ff=2816,   # 2x expert width
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, norm="rmsnorm", mlp="swiglu",
    n_experts=8, top_k=2, shared_expert_ff=128,
    capacity_factor=2.0, tp_target=4,
)
