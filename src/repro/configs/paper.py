"""The paper's own experimental configuration (LCI, §5).

LCI is a communication library, so its "config" is the microbenchmark
matrix rather than a model: message sizes, lane (thread) counts, resource
modes, and the platform constants the evaluation used.  The benchmark
harness (benchmarks/) reads this module so each figure's parameters live
in exactly one place.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.modes import CommConfig, CommMode


@dataclasses.dataclass(frozen=True)
class PaperBenchConfig:
    # Fig 2/3 — message rate: 8 B messages, 1..128 lanes ("threads")
    msg_rate_size: int = 8
    msg_rate_lanes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    msg_rate_iters: int = 2_000           # paper: 100k; scaled for CPU sim

    # Fig 4 — bandwidth: 64 lanes, 16 B .. 1 MiB
    bw_lanes: int = 64
    bw_sizes: Tuple[int, ...] = tuple(16 * 4 ** i for i in range(9))
    bw_iters: int = 50                    # paper: 1k; scaled

    # Fig 5 — individual resources: CQ / matching engine / packet pool
    resource_lanes: Tuple[int, ...] = (1, 4, 16, 64, 128)
    resource_iters: int = 5_000           # paper: 100k; scaled

    # Fig 6 — k-mer counting mini-app
    kmer_k: int = 11
    kmer_reads: int = 2_000
    kmer_read_len: int = 80
    kmer_ranks: Tuple[int, ...] = (2, 4, 8)
    kmer_agg_bytes: int = 8 * 1024        # paper: 8 KB aggregation buffers

    # Fig 7 — AMT pipeline (HPX/Octo-Tiger analogue): completion-graph
    # scheduled task DAG with comm edges
    amt_tasks: int = 256
    amt_ranks: int = 4

    # resource modes compared everywhere (paper's process/shared/dedicated)
    modes: Tuple[CommMode, ...] = (CommMode.BSP, CommMode.LCI_SHARED,
                                   CommMode.LCI_DEDICATED)


PAPER = PaperBenchConfig()


def comm_config(mode: CommMode, n_channels: int = 4) -> CommConfig:
    return CommConfig(mode=mode, n_channels=n_channels)
