"""olmoe-1b-7b [moe] — 64 experts top-8, qk-norm.

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304
[arXiv:2409.02060; hf]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    norm="rmsnorm", mlp="swiglu", qk_norm=True,
    n_experts=64, top_k=8, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, norm="rmsnorm", mlp="swiglu", qk_norm=True,
    n_experts=8, top_k=2, capacity_factor=2.0, tp_target=4,
)
