"""gemma3-1b [dense] — 5:1 local:global SWA, 128k context, qk-norm, geglu.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 head_dim=256
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    norm="rmsnorm", mlp="geglu", tie_embeddings=True, qk_norm=True,
    sliding_window=512, swa_every_nth_global=6,   # 5 local : 1 global
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=2, n_kv_heads=1,
    d_ff=192, vocab=512, head_dim=32, norm="rmsnorm", mlp="geglu",
    tie_embeddings=True, qk_norm=True, sliding_window=8,
    swa_every_nth_global=3, tp_target=4,
)
