"""olmo-1b [dense] — non-parametric LayerNorm, tied embeddings.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304 [arXiv:2402.00838; hf]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304,
    norm="layernorm_np",         # OLMo: LN without scale/bias
    mlp="swiglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab=512, norm="layernorm_np", mlp="swiglu",
    tie_embeddings=True, tp_target=4,
)
