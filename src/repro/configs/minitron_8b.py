"""minitron-8b [dense] — pruned Nemotron: squared-ReLU MLP, GQA kv=8.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000 [arXiv:2407.14679; hf]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000,
    norm="layernorm",            # Nemotron uses LayerNorm1p (~LN)
    mlp="relu2",
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=8, n_kv_heads=2,
    d_ff=192, vocab=512, head_dim=12, norm="layernorm", mlp="relu2",
    tp_target=4,
)
