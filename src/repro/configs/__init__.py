"""Config registry: 10 assigned architectures × 4 input shapes = 40 cells.

``get_config(arch)`` / ``get_smoke(arch)`` return ModelConfigs;
``SHAPES`` defines the assigned input-shape set; ``cells()`` enumerates
the runnable (arch × shape) grid with the documented skips:

* ``long_500k`` needs sub-quadratic attention → runs only for SSM/hybrid/
  sliding-window archs (mamba2, hymba, gemma3); skipped for pure
  full-attention archs (documented in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.common import ModelConfig

_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "olmo-1b": "olmo_1b",
    "gemma3-1b": "gemma3_1b",
    "minitron-8b": "minitron_8b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "mamba2-370m": "mamba2_370m",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_NAMES = list(_MODULES)

__all__ = ["ARCH_NAMES", "SHAPES", "Shape", "cells", "get_config",
           "get_smoke", "shape_applicable"]


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; pick from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> Tuple[bool, str]:
    """(runs?, reason) for one (arch × shape) cell."""
    if shape.name == "long_500k" and not cfg.uses_subquadratic_attention():
        return False, ("pure full-attention arch: 500k decode KV would be "
                       "quadratic-prefill territory; skipped per assignment")
    return True, ""


def cells(archs: Optional[List[str]] = None
          ) -> List[Tuple[str, str, bool, str]]:
    """All 40 cells: (arch, shape, runs, skip_reason)."""
    out = []
    for a in (archs or ARCH_NAMES):
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
