"""command-r-plus-104b [dense] — GQA kv=8, no-bias, parallel attn/FFN block.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, head_dim=128,
    norm="layernorm",            # Cohere uses (bias-free) LayerNorm
    mlp="swiglu", parallel_block=True, tie_embeddings=True,
    rope_theta=75_000_000.0,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16,
    norm="layernorm", mlp="swiglu", parallel_block=True,
    tie_embeddings=True, tp_target=4,
)
