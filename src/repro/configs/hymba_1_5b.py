"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16
SWA everywhere except 3 global layers (first/middle/last); meta tokens
omitted (frontend-independent backbone). [arXiv:2411.13676; hf]
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
    sliding_window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=5,
    d_ff=128, vocab=512, head_dim=16, norm="rmsnorm", mlp="swiglu",
    tie_embeddings=True, sliding_window=8, global_layers=(0,),
    ssm_state=8, ssm_headdim=16, ssm_chunk=8, tp_target=4,
)
