"""Version-compatibility shims over the installed jax.

The repo targets the modern jax surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); older releases
spell these ``jax.experimental.shard_map.shard_map(check_rep=...)`` and
have no ``AxisType``.  Everything that builds meshes or shard_maps goes
through this module so the version split lives in exactly one place.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if not _NEW_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _old_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; the experimental spelling on old jax
    (where ``check_vma`` was named ``check_rep``)."""
    if _NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_size(axis_name) -> int:
    """Static size of a bound mesh axis (``jax.lax.axis_size`` on new jax)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src.core import get_axis_env
    return get_axis_env().axis_size(axis_name)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              devices: Optional[Sequence[Any]] = None):
    """``jax.make_mesh`` with every axis in Auto mode where the installed
    jax knows about axis types; plain mesh otherwise (old jax is
    implicitly all-Auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {} if devices is None else {"devices": devices}
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
