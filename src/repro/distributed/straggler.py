"""Straggler detection & mitigation hooks (DESIGN.md §7).

On a real multi-pod job, per-step wall times are collected per host; a
host whose step times drift beyond a z-score threshold is flagged so the
launcher can (a) exclude it at the next elastic reshard, or (b) re-issue
its data shard through the backlog-queue path.  Here the monitor is the
single-process version of that machinery, used by the train loop and
covered by unit tests; the launcher consumes ``flagged``.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    dt: float
    mean: float
    std: float
    zscore: float


class StepTimeMonitor:
    """Sliding-window z-score flagging of slow steps/hosts."""

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.window = window
        self.z_threshold = z_threshold
        self.warmup = warmup
        self._times: Deque[float] = collections.deque(maxlen=window)
        self.reports: List[StragglerReport] = []
        self.flagged: List[StragglerReport] = []
        self._n = 0

    def record(self, step: int, dt: float) -> Optional[StragglerReport]:
        self._n += 1
        if len(self._times) >= self.warmup:
            mean = sum(self._times) / len(self._times)
            var = sum((t - mean) ** 2 for t in self._times) / len(self._times)
            std = math.sqrt(var)
            z = (dt - mean) / std if std > 1e-12 else 0.0
            rep = StragglerReport(step, dt, mean, std, z)
            self.reports.append(rep)
            if z > self.z_threshold:
                # flagged samples stay OUT of the window: a straggler
                # folded into the baseline inflates mean/std and masks
                # the next straggler (two slow steps in a row would
                # normalize each other)
                self.flagged.append(rep)
                return rep
        self._times.append(dt)
        return None

    def summary(self) -> Dict[str, float]:
        if not self._times:
            return {"mean": 0.0, "n": 0, "flagged": 0}
        return {"mean": sum(self._times) / len(self._times),
                "n": self._n, "flagged": len(self.flagged)}


class HostWatchdog:
    """Heartbeat bookkeeping for the launcher's failure detector.

    Hosts post monotonically increasing step heartbeats; ``dead_hosts``
    returns hosts whose heartbeat lags the median by more than ``grace``
    steps — the launcher restarts from the last committed checkpoint with
    the surviving host set (elastic restore handles the re-shard).
    """

    def __init__(self, n_hosts: int, grace: int = 10):
        self.n_hosts = n_hosts
        self.grace = grace
        self.heartbeat: Dict[int, int] = {h: 0 for h in range(n_hosts)}

    def beat(self, host: int, step: int) -> None:
        self.heartbeat[host] = max(self.heartbeat[host], step)

    def dead_hosts(self) -> List[int]:
        beats = sorted(self.heartbeat.values())
        median = beats[len(beats) // 2]
        return [h for h, b in self.heartbeat.items()
                if median - b > self.grace]
