"""The ``Comm`` object — how model code talks to the LCI-X layer.

Model code is written in *local view* (the shapes one device sees inside
``shard_map``), and every data movement goes through a :class:`Comm`, which
is the in-graph analogue of an LCI *device*: a full set of communication
resources the caller posts operations to.  Three deployments of the same
model code:

* **local** (``local_comm()``) — no mesh axes; every collective degenerates
  to its local computation.  Used by CPU smoke tests and single-chip runs.
* **shard_map manual** — axes bound; collectives lower to the explicit ring
  schedules of :mod:`repro.core.collectives` in the mode picked by
  ``CommConfig`` (BSP = paper's bulk-synchronous baseline, LCI_* = the
  paper's contribution).
* **GSPMD** (``model_axis=None`` but constraints on) — the escape hatch for
  comparing against XLA's automatic SPMD partitioner (§Perf).

Axis conventions (DESIGN.md §5): ``model`` = TP/EP/SP axis; ``data`` =
DP/FSDP axis (a tuple like ``("pod", "data")`` on the multi-pod mesh).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size

from repro.core import attrs as _attrs
from repro.core import collectives as C
from repro.core.modes import CommConfig, CommMode
from repro.core.progress import EndpointSpec

AxisSpec = Union[str, Tuple[str, ...], None]


def _axes(a: AxisSpec) -> Tuple[str, ...]:
    if a is None:
        return ()
    return (a,) if isinstance(a, str) else tuple(a)


@dataclasses.dataclass(frozen=True)
class Comm:
    """In-graph communication device handed to model code."""

    config: CommConfig
    model_axis: AxisSpec = None
    data_axis: AxisSpec = None
    fsdp: bool = True          # gather FSDP-dim weights in weight()
    # Endpoint spec: which resource bundle this Comm's collectives ride.
    # On the host runtime an EndpointSpec materializes as N devices; in
    # the in-graph layer the same knob selects the collective channel
    # count (chunk-streams) and the shared/dedicated schedule mode.
    endpoint: Optional[EndpointSpec] = None

    @property
    def cfg(self) -> CommConfig:
        """The CommConfig collectives actually run with: the endpoint spec
        overrides channel count and mode (BSP is never overridden — the
        baseline stays the baseline)."""
        if self.endpoint is None or self.config.mode == CommMode.BSP:
            return self.config
        # the progress policy alone picks the mode: a shared multi-device
        # spec stays LCI_SHARED (one chunk-stream), exactly as
        # EndpointSpec.for_mode round-trips it
        mode = (CommMode.LCI_DEDICATED
                if self.endpoint.progress == "dedicated"
                else CommMode.LCI_SHARED)
        return dataclasses.replace(self.config, mode=mode,
                                   n_channels=self.endpoint.n_devices)

    def with_endpoint(self, spec: EndpointSpec) -> "Comm":
        return dataclasses.replace(self, endpoint=spec)

    # -- attribute introspection (DESIGN.md §12): the Comm is a view over
    #    the effective config its collectives actually run with ----------
    def get_attr(self, name: str):
        """Query one attribute of the *effective* config (endpoint spec
        layered over the CommConfig), plus the discovered mesh widths
        ``tp``/``dp``.  Endpoint attrs (``stripe``/``progress``/
        ``n_devices``/...) resolve against the attached spec."""
        name = _attrs.canonical_name(name)
        if name == "tp":
            return self.tp
        if name == "dp":
            return self.dp
        if self.endpoint is not None:
            try:
                return self.endpoint.get_attr(name)
            except _attrs.AttrError:
                pass                       # not an endpoint attr: fall back
        return self.cfg.get_attr(name)

    @property
    def attrs(self) -> dict:
        out = dict(self.cfg.attrs)
        if self.endpoint is not None:
            out.update(self.endpoint.attrs)
        return out

    # -- axis sizes (1 when unbound) ----------------------------------------
    @property
    def tp(self) -> int:
        return math.prod([axis_size(a)
                          for a in _axes(self.model_axis)] or [1])

    @property
    def dp(self) -> int:
        return math.prod([axis_size(a)
                          for a in _axes(self.data_axis)] or [1])

    def _one_model_axis(self) -> Optional[str]:
        ax = _axes(self.model_axis)
        if len(ax) > 1:
            raise ValueError("model axis must be a single mesh axis")
        return ax[0] if ax else None

    # -- tensor-parallel matmuls (SP <-> TP boundary) ------------------------
    def ag_matmul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """``allgather(x, axis=0 over model) @ w`` — column-parallel entry.
        x: (s_local, ..., k) seq-sharded; w: (k, n_local)."""
        ax = self._one_model_axis()
        if ax is None:
            return jnp.tensordot(x, w, axes=1).astype(x.dtype)
        return C.all_gather_matmul(x, w, ax, self.cfg)

    def matmul_rs(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """``reduce_scatter(x @ w, axis=0 over model)`` — row-parallel exit.
        x: (s, ..., k_local); w: (k_local, n).  Returns (s/TP, ..., n)."""
        ax = self._one_model_axis()
        if ax is None:
            return jnp.tensordot(x, w, axes=1).astype(x.dtype)
        return C.matmul_reduce_scatter(x, w, ax, self.cfg)

    def matmul_ar(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """``allreduce(x @ w)`` — row-parallel exit without SP (decode path
        where s is tiny and scattering it is not possible)."""
        ax = self._one_model_axis()
        y = jnp.tensordot(x, w, axes=1).astype(x.dtype)
        if ax is None:
            return y
        return lax.psum(y, ax)

    # -- raw collectives over the model axis ---------------------------------
    def ag_seq(self, x: jax.Array, *, axis: int = 0) -> jax.Array:
        """All-gather the SP (sequence) dim back to full length."""
        ax = self._one_model_axis()
        if ax is None:
            return x
        return C.all_gather(x, ax, self.cfg, axis=axis)

    def rs_seq(self, x: jax.Array, *, axis: int = 0) -> jax.Array:
        ax = self._one_model_axis()
        if ax is None:
            return x
        return C.reduce_scatter(x, ax, self.cfg, axis=axis)

    def psum_model(self, x: jax.Array) -> jax.Array:
        ax = self._one_model_axis()
        if ax is None:
            return x
        return lax.psum(x, ax)

    def psum_model_ge(self, x: jax.Array) -> jax.Array:
        """Gradient-exact psum over the model axis.

        Under ``shard_map(check_vma=False)`` the transpose of ``psum`` is
        ``psum``, which overcounts cotangents by the axis size when the
        consumer (the loss) is *replicated* across the axis.  For that
        replicated-consumer case the exact transpose is identity: each
        rank's operand enters the sum with coefficient one.  Forward value
        is the psum; backward passes the cotangent through untouched::

            y = x + stop_gradient(psum(x) - x)

        Use this (not psum_model) on every differentiable reduction that
        feeds the replicated loss (vocab-parallel CE, SSM norm stats,
        router aux means) — tests/helpers/dist_equivalence.py asserts the
        resulting distributed grads equal the single-device oracle.
        """
        ax = self._one_model_axis()
        if ax is None:
            return x
        return x + lax.stop_gradient(lax.psum(x, ax) - x)

    def pmax_model(self, x: jax.Array) -> jax.Array:
        ax = self._one_model_axis()
        if ax is None:
            return x
        return lax.pmax(x, ax)

    def a2a(self, x: jax.Array, *, split_axis: int, concat_axis: int
            ) -> jax.Array:
        """All-to-all over the model axis (MoE dispatch/combine)."""
        ax = self._one_model_axis()
        if ax is None:
            return x
        return C.all_to_all(x, ax, split_axis=split_axis,
                            concat_axis=concat_axis, config=self.cfg)

    def model_index(self) -> jax.Array:
        ax = self._one_model_axis()
        if ax is None:
            return jnp.zeros((), jnp.int32)
        return lax.axis_index(ax)

    # -- FSDP (data axis) weight gather --------------------------------------
    def weight(self, w: jax.Array, *, fsdp_axis: Optional[int]) -> jax.Array:
        """Gather a weight's FSDP-sharded dim back to full size.

        This is the zero-copy bulk-transfer path (rendezvous protocol): in
        LCI modes it is a chunked ppermute ring whose steps XLA overlaps
        with the previous layer's compute; its VJP is the matching ring
        reduce(-scatter) of the weight gradient.
        """
        if fsdp_axis is None or not self.fsdp:
            return w
        axes = _axes(self.data_axis)
        if not axes:
            return w
        for a in reversed(axes):          # innermost axis gathered first
            w = C.all_gather(w, a, self.cfg, axis=fsdp_axis)
        return w

    # -- data-parallel reductions (loss/grad sync) ----------------------------
    def psum_data(self, x: jax.Array) -> jax.Array:
        axes = _axes(self.data_axis)
        for a in axes:
            x = lax.psum(x, a)
        return x

    def data_index(self) -> jax.Array:
        """Flat index along the (possibly multi-axis) data dimension."""
        idx = jnp.zeros((), jnp.int32)
        for a in _axes(self.data_axis):
            idx = idx * axis_size(a) + lax.axis_index(a)
        return idx

    def ag_data(self, x: jax.Array, *, axis: int) -> jax.Array:
        """All-gather over the data axes along ``axis`` (tiny tensors —
        the 2D-TP serving column reassembly)."""
        for a in reversed(_axes(self.data_axis)):
            x = C.all_gather(x, a, self.cfg, axis=axis)
        return x

    def pmean_data(self, x: jax.Array) -> jax.Array:
        axes = _axes(self.data_axis)
        if not axes:
            return x
        return jax.tree_util.tree_map(
            lambda v: self.psum_data(v) / self.dp, x)

    def psum_all(self, x: jax.Array) -> jax.Array:
        return self.psum_model(self.psum_data(x))

    def pmean_all(self, x: jax.Array) -> jax.Array:
        """Mean over every mesh axis — makes a metric fully replicated."""
        n = self.tp * self.dp
        return jax.tree_util.tree_map(
            lambda v: self.psum_all(v) / n, x)

    # -- barrier (paper §6 primitive, used by the launcher) -------------------
    def barrier(self) -> jax.Array:
        ax = self._one_model_axis()
        tok = jnp.ones((), jnp.int32)
        if ax is not None:
            tok = C.dissemination_barrier(ax)
        for a in _axes(self.data_axis):
            tok = tok * 0 + C.dissemination_barrier(a)
        return tok


def local_comm(config: Optional[CommConfig] = None) -> Comm:
    """A Comm with no mesh axes: collectives degenerate to local compute."""
    return Comm(config or CommConfig(), model_axis=None, data_axis=None)
