"""Gradient compression: int8 quantization + error feedback.

A zero-copy-protocol transform on the DP gradient reduce (DESIGN.md §7):
before the data-axis ring reduction, each shard's gradient is quantized
to int8 with a per-tensor fp32 scale; the quantization residual is kept
locally and added back into the *next* step's gradient (error feedback —
the standard trick that keeps SGD-style convergence).  Off by default;
the convergence test (tests/test_distributed_features.py) trains twice
and asserts compressed training tracks the uncompressed loss.

On the wire this cuts DP gradient bytes 4× (fp32) / 2× (bf16); the ring
all-reduce then moves int8 payloads (sum in int32, rescale after).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grad(g: jax.Array, error: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One tensor: returns (q int8, scale, new_error)."""
    corrected = g.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected)
    new_error = corrected - dequantize_int8(q, scale)
    return q, scale, new_error


def compressed_psum_data(g: jax.Array, error: jax.Array, comm
                         ) -> Tuple[jax.Array, jax.Array]:
    """DP mean of one gradient tensor through the int8 wire format.

    The int8 payload is summed in int32 across the data axis (exact: dp ≤
    512 keeps |sum| < 2^15), scales are averaged — a 4×-narrower ring.
    Returns (reduced fp32 grad, new local error).
    """
    q, scale, new_error = compress_grad(g, error)
    qsum = comm.psum_data(q.astype(jnp.int32))
    ssum = comm.psum_data(scale)
    # mean over dp of per-rank (q_i * scale_i) ≈ (Σq_i) * mean(scale)/dp
    dp = comm.dp
    out = qsum.astype(jnp.float32) * (ssum / dp) / dp
    return out.astype(g.dtype), new_error


def init_error_state(grads_like: Dict[str, Any]) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def grad_sync_compressed(grads, specs, error_state, comm):
    """Drop-in alternative to optim.grad_sync with int8 error feedback.

    Model-axis reductions stay exact (they carry activation-gradient
    semantics); only the DP mean is compressed, mirroring production
    systems that compress the inter-node hop only.
    """
    from repro.models.common import ParamSpec

    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    leaves_e = jax.tree_util.tree_leaves(error_state)
    out_g, out_e = [], []
    dp = comm.dp
    for g, sp, e in zip(leaves_g, leaves_s, leaves_e):
        if sp.tp_axis is None:
            g = comm.psum_model(g)
        if sp.fsdp_axis is None:
            g2, e2 = compressed_psum_data(g, e, comm)
        else:
            # AD already summed over data; quantize the local shard only
            # (keeps the error-feedback state consistent) then rescale
            g2, e2 = (g / dp).astype(g.dtype), e
        out_g.append(g2)
        out_e.append(e2)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))
