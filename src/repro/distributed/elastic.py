"""Elastic scaling: reshard a running job onto a different mesh.

The mechanism (DESIGN.md §7): checkpoints store *global* arrays with a
manifest; :func:`reshard_state` places them under the NEW mesh's
NamedShardings (``jax.device_put`` re-chunks).  The launcher flow on a
node failure / resize:

    1. watchdog flags dead hosts (distributed.straggler.HostWatchdog)
    2. survivors agree on the new mesh (next divisor-compatible shape)
    3. restore_resharded(ckpt, tree, new_shardings)
    4. data pipeline replays from manifest["next_step"] — bit-exact

``compatible_meshes`` enumerates legal (data, model) shapes for a config
(the model axis must divide every TP-sharded dim).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.common import ModelConfig, shard_decisions


def compatible_meshes(cfg: ModelConfig, n_devices: int
                      ) -> List[Tuple[int, int]]:
    """All (data, model) shapes on n_devices this config can run under."""
    dec = shard_decisions(cfg)
    out = []
    for model in range(1, n_devices + 1):
        if n_devices % model:
            continue
        data = n_devices // model
        if dec["attn"] and model > 1 and cfg.n_heads % model:
            continue
        if dec["ssm"] and model > 1 and cfg.ssm_heads % model:
            continue
        if cfg.n_experts and model > 1 and cfg.n_experts % model:
            continue
        if cfg.padded_vocab % model:
            continue
        out.append((data, model))
    return out


def reshard_state(state: Any, shardings: Any) -> Any:
    """Place every leaf with the new mesh's sharding (re-chunking move)."""
    return jax.tree_util.tree_map(
        lambda x, sh: jax.device_put(x, sh), state, shardings)


def shrink_mesh(old_shape: Tuple[int, ...], dead_fraction: float,
                cfg: Optional[ModelConfig] = None
                ) -> Tuple[int, ...]:
    """Pick the largest compatible mesh after losing ``dead_fraction``.

    Without ``cfg`` the model axis is kept and DP shrinks (every DP
    width is legal).  With ``cfg`` the answer must divide the model's
    sharded dims, so we snap to the largest shape ``compatible_meshes``
    allows on any device count <= the survivor count — including moving
    work off the model axis when the old width no longer fits.
    """
    import math
    n_old = math.prod(old_shape)
    target = int(n_old * (1 - dead_fraction))
    if cfg is None:
        # keep the model axis, shrink data (DP is the elastic axis)
        model = old_shape[-1]
        data = max(1, target // model)
        return (data, model)
    old_model = old_shape[-1]
    best: Optional[Tuple[int, int]] = None
    best_key = None
    for n in range(max(1, target), 0, -1):
        for data, model in compatible_meshes(cfg, n):
            # prefer more total devices, then keeping the old model
            # width (cheapest re-shard), then wider DP
            key = (data * model, model == old_model, data)
            if best_key is None or key > best_key:
                best, best_key = (data, model), key
        if best is not None:
            break                    # n is scanned largest-first
    if best is None:
        raise ValueError(
            f"shrink_mesh: no mesh on <= {target} device(s) is compatible "
            f"with this config (model axis must divide heads/experts/"
            f"vocab); survivors cannot host the model")
    return best
