"""Pipeline parallelism scheduled as an LCI completion graph (1F1B).

The paper's completion graph ("operations + user functions with a partial
execution order ... every ready node fires immediately") is exactly a
pipeline schedule: node (s, m, dir) = stage s processing microbatch m in
direction fwd/bwd, edges = (a) stage order within a microbatch, (b) the
1F1B resource constraint within a stage.  Building the schedule as a
:class:`repro.core.graph.CompletionGraph` gives us the paper's semantics
(fire order = completion order) plus its introspection: the critical path
length of the graph IS the pipeline's bubble-inclusive step count.

Three deployments:

* :func:`schedule_1f1b` — build + validate the schedule (tested against
  the analytic bubble formula);
* :func:`build_1f1b_comm_graph` — the *async* deployment: one cluster
  rank per stage, activation hand-offs as real send/recv **comm nodes**
  riding per-stage endpoints.  ``graph.start()`` posts the ready ops, the
  progress engine signals completions, and downstream stages fire as
  signals arrive — the paper's graph-completed-by-progress-engine
  semantics end to end (no host-side synchronous fire).
* :class:`PipelinedModel` — run a stage-split model on the host schedule,
  stages mapped to mesh slices, activations moved stage→stage with
  ppermute (the comm edges of the graph).  Here stages run sequentially
  on one host (the dry-run proves the mesh variant; PP is an optional
  extra axis for deeper-than-ICI models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CompletionGraph
from repro.core.post import post_recv_x, post_send_x


@dataclasses.dataclass(frozen=True)
class PPNode:
    stage: int
    micro: int
    is_fwd: bool


def schedule_1f1b(n_stages: int, n_micro: int
                  ) -> Tuple[CompletionGraph, Dict[PPNode, int]]:
    """Build the 1F1B dependency graph (no weights, pure schedule).

    Edges:
      fwd(s, m)  needs fwd(s-1, m)
      bwd(s, m)  needs bwd(s+1, m) and fwd(s, m)
      1F1B steady state: fwd(s, m) needs bwd(s, m - (n_stages - s))
      (limits in-flight microbatches per stage = its warmup depth)
    """
    g = CompletionGraph("1f1b")
    ids: Dict[PPNode, int] = {}

    def deps_of(node: PPNode) -> List[PPNode]:
        s, m = node.stage, node.micro
        if node.is_fwd:
            deps = []
            if s > 0:
                deps.append(PPNode(s - 1, m, True))
            lookback = m - (n_stages - s)       # 1F1B in-flight limit
            if lookback >= 0:
                deps.append(PPNode(s, lookback, False))
            return deps
        deps = [PPNode(s, m, True)]
        if s < n_stages - 1:
            deps.append(PPNode(s + 1, m, False))
        return deps

    # insert in a dependency-satisfying order (1F1B interleaves fwd/bwd,
    # so neither all-fwd-first nor per-microbatch order is topological)
    pending = [PPNode(s, m, f) for m in range(n_micro)
               for s in range(n_stages) for f in (True, False)]
    while pending:
        progressed = False
        rest = []
        for node in pending:
            deps = deps_of(node)
            if all(d in ids for d in deps):
                ids[node] = g.add_node(
                    lambda *a, n=node: n, deps=[ids[d] for d in deps],
                    name=f"{'F' if node.is_fwd else 'B'}"
                         f"{node.stage}.{node.micro}")
                progressed = True
            else:
                rest.append(node)
        if not progressed:
            raise RuntimeError("1F1B schedule has a dependency cycle")
        pending = rest
    return g, ids


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Analytic 1F1B bubble: (S-1) / (S-1+M) of the step is idle."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)


@dataclasses.dataclass
class PipelineCommGraph:
    """The async 1F1B deployment: graph + node maps + landing buffers."""

    graph: CompletionGraph
    compute_ids: Dict[PPNode, int]          # (stage, micro, dir) -> node id
    comm_ids: Dict[Tuple[str, int, int], int]   # ("SF"/"RF"/"SB"/"RB", s, m)
    act_in: Dict[Tuple[int, int], np.ndarray]   # fwd landing at stage s+1
    grad_in: Dict[Tuple[int, int], np.ndarray]  # bwd landing at stage s


def build_1f1b_comm_graph(cluster, n_micro: int, payload_bytes: int = 32,
                          endpoints: Optional[List] = None,
                          fwd_fn: Optional[Callable] = None,
                          bwd_fn: Optional[Callable] = None
                          ) -> PipelineCommGraph:
    """1F1B with activation hand-offs as *real comm nodes* — one cluster
    rank per stage; fwd activations and bwd grads ride the fabric.

    Node kinds per (stage s, micro m):

    * ``CF``/``CB`` — host compute (fn nodes); ``fwd_fn(x, s, m) -> bytes``
      maps the incoming activation, ``bwd_fn(g, s, m) -> bytes`` the
      incoming gradient (defaults: mod-251 marker arithmetic so tests can
      assert end-to-end content).
    * ``SF``/``RF`` — send/recv of the fwd activation s → s+1 (comm nodes,
      tag ``2m``); ``SB``/``RB`` — the bwd gradient s → s-1 (tag ``2m+1``).

    Dependencies keep the paper schedule: ``CF`` needs its ``RF`` plus the
    1F1B lookback edge to ``CB(s, m-(S-s))``; ``CB`` needs ``CF`` and its
    ``RB``.  Receives are pre-posted at ``start()`` (no deps): the matching
    engine pairs them with sends whenever they arrive; *completion* still
    follows the wire, which is what the partial order asserts.

    ``endpoints`` (optional, one per rank) routes every comm node through
    that rank's striped endpoint via ``.endpoint(...)``.
    """
    n_stages = cluster.n_ranks
    if n_stages < 2:
        raise ValueError("async 1F1B needs >= 2 stages (cluster ranks)")
    fwd_fn = fwd_fn or (lambda x, s, m: (x + s + 1) % 251)
    bwd_fn = bwd_fn or (lambda g, s, m: (g * 2 + s) % 251)

    g = CompletionGraph("1f1b-comm")
    act_in = {(s, m): np.zeros(payload_bytes, np.uint8)
              for s in range(n_stages - 1) for m in range(n_micro)}
    act_out = {(s, m): np.zeros(payload_bytes, np.uint8)
               for s in range(n_stages - 1) for m in range(n_micro)}
    grad_in = {(s, m): np.zeros(payload_bytes, np.uint8)
               for s in range(n_stages - 1) for m in range(n_micro)}
    grad_out = {(s, m): np.zeros(payload_bytes, np.uint8)
                for s in range(1, n_stages) for m in range(n_micro)}

    def _ep(rank):
        return endpoints[rank] if endpoints is not None else None

    def _comm(builder, rank):
        ep = _ep(rank)
        return builder.endpoint(ep) if ep is not None else builder

    def make_cf(s, m):
        def cf(*_deps):
            x = act_in[(s - 1, m)] if s > 0 else \
                np.full(payload_bytes, m % 251, np.uint8)
            y = fwd_fn(x.astype(np.int64), s, m).astype(np.uint8)
            if s < n_stages - 1:
                act_out[(s, m)][:] = y
            return y
        return cf

    def make_cb(s, m):
        def cb(*_deps):
            gsrc = grad_in[(s, m)] if s < n_stages - 1 else \
                compute_vals[PPNode(s, m, True)]
            gy = bwd_fn(gsrc.astype(np.int64), s, m).astype(np.uint8)
            if s > 0:
                grad_out[(s, m)][:] = gy
            return gy
        return cb

    compute_vals: Dict[PPNode, np.ndarray] = {}

    def make_record(node, fn):
        def wrapped(*deps):
            out = fn(*deps)
            compute_vals[node] = out
            return out
        return wrapped

    # descriptor -> (dep descriptors); inserted via the same worklist
    # approach as schedule_1f1b (1F1B interleaving is not insertion-ordered)
    def deps_of(kind, s, m):
        if kind in ("RF", "RB"):
            return []
        if kind == "CF":
            # RF/SF are keyed by the *sender* stage: stage s consumes the
            # landing of the s-1 -> s activation
            deps = [("RF", s - 1, m)] if s > 0 else []
            lb = m - (n_stages - s)
            if lb >= 0:
                deps.append(("CB", s, lb))
            return deps
        if kind == "SF":
            return [("CF", s, m)]
        if kind == "CB":
            deps = [("CF", s, m)]
            if s < n_stages - 1:
                deps.append(("RB", s, m))
            return deps
        return [("CB", s, m)]                           # SB

    def builder_of(kind, s, m):
        if kind == "SF":   # fwd activation s -> s+1, tag 2m
            return _comm(post_send_x(cluster[s], s + 1, act_out[(s, m)],
                                     payload_bytes, 2 * m), s)
        if kind == "RF":   # landing at s+1 for the s -> s+1 activation
            return _comm(post_recv_x(cluster[s + 1], s, act_in[(s, m)],
                                     payload_bytes, 2 * m), s + 1)
        if kind == "SB":   # bwd grad s -> s-1, tag 2m+1
            return _comm(post_send_x(cluster[s], s - 1, grad_out[(s, m)],
                                     payload_bytes, 2 * m + 1), s)
        # RB: landing at s for the s+1 -> s gradient
        return _comm(post_recv_x(cluster[s], s + 1, grad_in[(s, m)],
                                 payload_bytes, 2 * m + 1), s)

    todo = []
    for m in range(n_micro):
        for s in range(n_stages):
            todo.append(("CF", s, m))
            todo.append(("CB", s, m))
            if s < n_stages - 1:
                todo.append(("SF", s, m))
                todo.append(("RF", s, m))       # lands at s+1
                todo.append(("RB", s, m))       # lands at s
            if s > 0:
                todo.append(("SB", s, m))

    ids: Dict[Tuple[str, int, int], int] = {}
    while todo:
        progressed, rest = False, []
        for key in todo:
            kind, s, m = key
            dep_keys = deps_of(kind, s, m)
            if not all(d in ids for d in dep_keys):
                rest.append(key)
                continue
            dep_ids = [ids[d] for d in dep_keys]
            name = f"{kind}{s}.{m}"
            if kind in ("CF", "CB"):
                node = PPNode(s, m, kind == "CF")
                fn = make_record(node, make_cf(s, m) if kind == "CF"
                                 else make_cb(s, m))
                ids[key] = g.add_node(fn, deps=dep_ids, name=name)
            else:
                ids[key] = g.add_comm(builder_of(kind, s, m),
                                      deps=dep_ids, name=name)
            progressed = True
        if not progressed:
            raise RuntimeError("1F1B comm schedule has a dependency cycle")
        todo = rest

    compute_ids = {PPNode(s, m, f): ids[("CF" if f else "CB", s, m)]
                   for s in range(n_stages) for m in range(n_micro)
                   for f in (True, False)}
    comm_ids = {k: v for k, v in ids.items() if k[0] not in ("CF", "CB")}
    g.add_progress(cluster)
    return PipelineCommGraph(g, compute_ids, comm_ids, act_in, grad_in)


class PipelinedModel:
    """Stage-split training on the completion-graph schedule.

    ``stage_fns[s](params_s, x) -> y`` for forward; backward is JAX AD per
    stage with explicit activation hand-off — the graph supplies the
    order, this class supplies the dataflow.  Single-host reference
    implementation (semantics + tests); the dry-run meshes cover the
    scale-out axes (DP/TP/FSDP); PP composes on top for >ICI-depth models.
    """

    def __init__(self, stage_fns: List[Callable], n_micro: int):
        self.stage_fns = stage_fns
        self.n_stages = len(stage_fns)
        self.n_micro = n_micro

    def forward_backward(self, stage_params: List[Any], micro_xs: List[Any],
                         loss_fn: Callable) -> Tuple[jax.Array, List[Any]]:
        """Returns (mean loss, per-stage grads summed over microbatches)."""
        graph, ids = schedule_1f1b(self.n_stages, self.n_micro)
        acts: Dict[Tuple[int, int], Any] = {}
        dacts: Dict[Tuple[int, int], Any] = {}
        grads = [jax.tree_util.tree_map(jnp.zeros_like, p)
                 for p in stage_params]
        losses = []

        graph.execute()                       # fire order with 1F1B deps
        for nid in graph.fire_order:
            node = graph.value(nid)
            s, m = node.stage, node.micro
            if node.is_fwd:
                x = micro_xs[m] if s == 0 else acts[(s - 1, m)]
                acts[(s, m)] = self.stage_fns[s](stage_params[s], x)
            else:
                x = micro_xs[m] if s == 0 else acts[(s - 1, m)]

                if s == self.n_stages - 1:
                    def head(p, xin):
                        y = self.stage_fns[s](p, xin)
                        return loss_fn(y, m)          # scalar loss
                    loss, (gp, gx) = jax.value_and_grad(
                        head, argnums=(0, 1))(stage_params[s], x)
                    losses.append(loss)
                else:
                    dy = dacts[(s + 1, m)]
                    _, vjp = jax.vjp(
                        lambda p, xin: self.stage_fns[s](p, xin),
                        stage_params[s], x)
                    gp, gx = vjp(dy)
                grads[s] = jax.tree_util.tree_map(
                    jnp.add, grads[s], gp)
                dacts[(s, m)] = gx
        graph.assert_partial_order()
        return jnp.mean(jnp.stack(losses)), grads
