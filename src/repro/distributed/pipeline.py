"""Pipeline parallelism scheduled as an LCI completion graph (1F1B).

The paper's completion graph ("operations + user functions with a partial
execution order ... every ready node fires immediately") is exactly a
pipeline schedule: node (s, m, dir) = stage s processing microbatch m in
direction fwd/bwd, edges = (a) stage order within a microbatch, (b) the
1F1B resource constraint within a stage.  Building the schedule as a
:class:`repro.core.graph.CompletionGraph` gives us the paper's semantics
(fire order = completion order) plus its introspection: the critical path
length of the graph IS the pipeline's bubble-inclusive step count.

Two deployments:

* :func:`schedule_1f1b` — build + validate the schedule (tested against
  the analytic bubble formula);
* :class:`PipelinedModel` — run a stage-split model on it, stages mapped
  to mesh slices, activations moved stage→stage with ppermute (the comm
  edges of the graph).  Here stages run sequentially on one host (the
  dry-run proves the mesh variant; PP is an optional extra axis for
  deeper-than-ICI models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import CompletionGraph


@dataclasses.dataclass(frozen=True)
class PPNode:
    stage: int
    micro: int
    is_fwd: bool


def schedule_1f1b(n_stages: int, n_micro: int
                  ) -> Tuple[CompletionGraph, Dict[PPNode, int]]:
    """Build the 1F1B dependency graph (no weights, pure schedule).

    Edges:
      fwd(s, m)  needs fwd(s-1, m)
      bwd(s, m)  needs bwd(s+1, m) and fwd(s, m)
      1F1B steady state: fwd(s, m) needs bwd(s, m - (n_stages - s))
      (limits in-flight microbatches per stage = its warmup depth)
    """
    g = CompletionGraph("1f1b")
    ids: Dict[PPNode, int] = {}

    def deps_of(node: PPNode) -> List[PPNode]:
        s, m = node.stage, node.micro
        if node.is_fwd:
            deps = []
            if s > 0:
                deps.append(PPNode(s - 1, m, True))
            lookback = m - (n_stages - s)       # 1F1B in-flight limit
            if lookback >= 0:
                deps.append(PPNode(s, lookback, False))
            return deps
        deps = [PPNode(s, m, True)]
        if s < n_stages - 1:
            deps.append(PPNode(s + 1, m, False))
        return deps

    # insert in a dependency-satisfying order (1F1B interleaves fwd/bwd,
    # so neither all-fwd-first nor per-microbatch order is topological)
    pending = [PPNode(s, m, f) for m in range(n_micro)
               for s in range(n_stages) for f in (True, False)]
    while pending:
        progressed = False
        rest = []
        for node in pending:
            deps = deps_of(node)
            if all(d in ids for d in deps):
                ids[node] = g.add_node(
                    lambda *a, n=node: n, deps=[ids[d] for d in deps],
                    name=f"{'F' if node.is_fwd else 'B'}"
                         f"{node.stage}.{node.micro}")
                progressed = True
            else:
                rest.append(node)
        if not progressed:
            raise RuntimeError("1F1B schedule has a dependency cycle")
        pending = rest
    return g, ids


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Analytic 1F1B bubble: (S-1) / (S-1+M) of the step is idle."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)


class PipelinedModel:
    """Stage-split training on the completion-graph schedule.

    ``stage_fns[s](params_s, x) -> y`` for forward; backward is JAX AD per
    stage with explicit activation hand-off — the graph supplies the
    order, this class supplies the dataflow.  Single-host reference
    implementation (semantics + tests); the dry-run meshes cover the
    scale-out axes (DP/TP/FSDP); PP composes on top for >ICI-depth models.
    """

    def __init__(self, stage_fns: List[Callable], n_micro: int):
        self.stage_fns = stage_fns
        self.n_stages = len(stage_fns)
        self.n_micro = n_micro

    def forward_backward(self, stage_params: List[Any], micro_xs: List[Any],
                         loss_fn: Callable) -> Tuple[jax.Array, List[Any]]:
        """Returns (mean loss, per-stage grads summed over microbatches)."""
        graph, ids = schedule_1f1b(self.n_stages, self.n_micro)
        acts: Dict[Tuple[int, int], Any] = {}
        dacts: Dict[Tuple[int, int], Any] = {}
        grads = [jax.tree_util.tree_map(jnp.zeros_like, p)
                 for p in stage_params]
        losses = []

        graph.execute()                       # fire order with 1F1B deps
        for nid in graph.fire_order:
            node = graph.value(nid)
            s, m = node.stage, node.micro
            if node.is_fwd:
                x = micro_xs[m] if s == 0 else acts[(s - 1, m)]
                acts[(s, m)] = self.stage_fns[s](stage_params[s], x)
            else:
                x = micro_xs[m] if s == 0 else acts[(s - 1, m)]

                if s == self.n_stages - 1:
                    def head(p, xin):
                        y = self.stage_fns[s](p, xin)
                        return loss_fn(y, m)          # scalar loss
                    loss, (gp, gx) = jax.value_and_grad(
                        head, argnums=(0, 1))(stage_params[s], x)
                    losses.append(loss)
                else:
                    dy = dacts[(s + 1, m)]
                    _, vjp = jax.vjp(
                        lambda p, xin: self.stage_fns[s](p, xin),
                        stage_params[s], x)
                    gp, gx = vjp(dy)
                grads[s] = jax.tree_util.tree_map(
                    jnp.add, grads[s], gp)
                dacts[(s, m)] = gx
        graph.assert_partial_order()
        return jnp.mean(jnp.stack(losses)), grads
