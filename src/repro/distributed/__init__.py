"""Distributed layer: comm abstraction, sharding rules, pipeline, and
resilience features (compression, elastic resharding, stragglers)."""
from .comm import Comm, local_comm

__all__ = ["Comm", "local_comm"]
