"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed on the
(16,16) single-pod mesh AND the (2,16,16) multi-pod mesh for all 40 cells;
``memory_analysis()`` proves residency, ``cost_analysis()`` + HLO
collective parsing feed the roofline (EXPERIMENTS.md §Roofline).

Usage::

    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --all            # orchestrates subprocesses
    python -m repro.launch.dryrun --all --mesh multi

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>__<mode>.json
"""
# The first two lines MUST precede any other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import math
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional, Tuple

from repro.compat import shard_map

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_NAMES, SHAPES, cells, get_config,
                           shape_applicable)
from repro.core.modes import CommConfig, CommMode, parse_mode
from repro.launch.mesh import (batch_pspecs, data_axes, make_comm,
                               make_production_mesh, shard)
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.serving.engine import cache_pspecs, init_cache
from repro.train.step import make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# abstract inputs per cell
# ---------------------------------------------------------------------------

def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def input_specs(cfg: ModelConfig, shape, mesh) -> Tuple[Dict, Dict]:
    """ShapeDtypeStruct stand-ins + pspecs for the batch of one cell."""
    s, b = shape.seq_len, shape.global_batch
    kind = shape.kind
    specs = batch_pspecs(cfg, kind, mesh, batch=b)
    batch: Dict[str, Any] = {}
    if kind == "decode":
        batch["tokens"] = SDS((b,), jnp.int32)
    else:
        batch["tokens"] = SDS((s, b), jnp.int32)
        if kind == "train":
            batch["labels"] = SDS((s, b), jnp.int32)
        else:
            specs.pop("labels", None)
    if cfg.family == "vlm" and kind != "decode":
        batch["image_embeds"] = SDS((cfg.n_image_tokens, b), jnp.bfloat16)
        batch["image_embeds"] = SDS(
            (cfg.n_image_tokens, b, cfg.d_model), cfg.dtype)
    if cfg.is_encdec and kind != "decode":
        t = _pad_to(cfg.n_audio_frames, 16)      # frames shard over model
        batch["frames"] = SDS((t, b, cfg.d_model), cfg.dtype)
    specs = {k: v for k, v in specs.items() if k in batch}
    return batch, specs


def n_memory_tokens(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.n_image_tokens
    if cfg.is_encdec:
        return _pad_to(cfg.n_audio_frames, 16)
    return 0


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+((?:\w+\[[^\]]*\](?:\{[^}]*\})?,?\s*)+|\([^)]*\))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> Dict[str, Any]:
    """Per-collective transfer accounting from optimized HLO text.

    Per-device transferred-bytes model (ring algorithms):
      collective-permute: result bytes (one hop);
      all-gather: result·(P-1)/P; reduce-scatter: result·(P-1);
      all-reduce: 2·result·(P-1)/P; all-to-all: result·(P-1)/P.
    """
    ops = []
    for m in _COLL_RE.finditer(hlo):
        shape_str, kind, _ = m.group(1), m.group(2), m.group(3)
        result_bytes = _shape_bytes(shape_str)
        tail = hlo[m.end():m.end() + 2000]
        g = _GROUPS_RE.search(tail)
        if g:
            p = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(tail)
            p = int(gi.group(2)) if gi else 1
        if kind == "collective-permute":
            xfer = result_bytes
        elif kind == "all-gather":
            xfer = result_bytes * (p - 1) // max(p, 1)
        elif kind == "reduce-scatter":
            xfer = result_bytes * (p - 1)
        elif kind == "all-reduce":
            xfer = 2 * result_bytes * (p - 1) // max(p, 1)
        else:                                   # all-to-all
            xfer = result_bytes * (p - 1) // max(p, 1)
        ops.append({"kind": kind, "result_bytes": result_bytes,
                    "group_size": p, "xfer_bytes": xfer})

    summary: Dict[str, Any] = {"n_ops": len(ops), "by_kind": {}, "ops": ops}
    for o in ops:
        k = summary["by_kind"].setdefault(
            o["kind"], {"count": 0, "xfer_bytes": 0})
        k["count"] += 1
        k["xfer_bytes"] += o["xfer_bytes"]
    summary["total_xfer_bytes"] = sum(o["xfer_bytes"] for o in ops)
    return summary


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, mode: CommMode,
               *, remat: bool = True, tp2d: bool = False,
               fsdp: bool = True, tp_mlp: bool = True,
               wire_bf16: bool = False, pad_heads: bool = False):
    """Returns (jitted_fn, abstract_args tuple)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if pad_heads:
        # §Perf cell 4: pad head counts to the model-axis width so the
        # attention and SSD branches shard instead of replicating
        # (hymba: 25->32 q heads, 5->8 kv, 50->64 SSD heads via headdim)
        def _pad(n, t):
            return ((n + t - 1) // t) * t
        t = cfg.tp_target
        updates = {"n_heads": _pad(cfg.n_heads, t),
                   "n_kv_heads": _pad(cfg.n_kv_heads, t // 2)}
        if cfg.ssm_state and cfg.ssm_heads % t:
            padded_heads = _pad(cfg.ssm_heads, t)
            updates["ssm_headdim"] = cfg.ssm_d_inner // padded_heads
        cfg = _dc.replace(cfg, **updates)
    if not fsdp:
        cfg = _dc.replace(cfg, fsdp_params=False)
    if not tp_mlp:
        cfg = _dc.replace(cfg, tp_mlp=False)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    comm = make_comm(mesh, CommConfig(mode=mode, wire_bf16=wire_bf16),
                     fsdp=cfg.fsdp_params)
    daxes = data_axes(mesh)

    params_abs = jax.eval_shape(
        lambda k: model.init(k)[0], jax.random.PRNGKey(0))
    _, pspecs_tree = model.abstract_params()
    param_pspecs = jax.tree_util.tree_map(
        lambda sp: sp.pspec(data_axis=daxes), pspecs_tree)
    batch_abs, bspecs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                                 params_abs)
        from repro.optim.adamw import OptState
        from repro.train.step import TrainState
        state_abs = TrainState(params_abs, opt_abs)
        state_specs = TrainState(
            param_pspecs,
            OptState(step=P(), mu=param_pspecs, nu=param_pspecs,
                     master=param_pspecs))
        step = make_train_step(model, pspecs_tree, opt_cfg, comm,
                               remat=remat)
        metric_keys = ("loss", "ce", "ntok", "aux_lb", "aux_z",
                       "dropped_frac", "grad_norm")
        mspecs = {k: P() for k in metric_keys}
        fn = shard_map(step, mesh=mesh,
                           in_specs=(state_specs, bspecs),
                           out_specs=(state_specs, mspecs),
                           check_vma=False)
        jitted = jax.jit(fn, in_shardings=(shard(mesh, state_specs),
                                           shard(mesh, bspecs)),
                         donate_argnums=(0,))
        return jitted, fn, (state_abs, batch_abs)

    if shape.kind == "prefill":
        from repro.serving.engine import make_prefill_step
        prefill = make_prefill_step(cfg, comm)
        out_specs = (P(daxes), P(daxes, None))
        fn = shard_map(prefill, mesh=mesh,
                           in_specs=(param_pspecs, bspecs),
                           out_specs=out_specs, check_vma=False)
        jitted = jax.jit(fn, in_shardings=(shard(mesh, param_pspecs),
                                           shard(mesh, bspecs)))
        return jitted, fn, (params_abs, batch_abs)

    # decode
    from repro.serving.engine import make_serve_step
    b = shape.global_batch
    joint = b == 1
    serve = make_serve_step(cfg, comm, joint_kv=joint, tp2d=tp2d)
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shape.seq_len, b,
                           n_memory=n_memory_tokens(cfg)))
    cspecs = cache_pspecs(cfg, batch=b, data_axis=daxes, tp2d=tp2d)
    tok_spec = P() if (joint or tp2d) else P(daxes)
    fn = shard_map(serve, mesh=mesh,
                       in_specs=(param_pspecs, cspecs, tok_spec),
                       out_specs=(tok_spec, cspecs), check_vma=False)
    jitted = jax.jit(fn, in_shardings=(shard(mesh, param_pspecs),
                                       shard(mesh, cspecs),
                                       NamedSharding(mesh, tok_spec)),
                     donate_argnums=(1,))
    return jitted, fn, (params_abs, cache_abs, batch_abs["tokens"])


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: CommMode,
             *, remat: bool = True, save: bool = True,
             tp2d: bool = False, fsdp: bool = True,
             tp_mlp: bool = True, wire_bf16: bool = False,
             pad_heads: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    variant = ("+tp2d" if tp2d else "") + ("" if fsdp else "+nofsdp") \
        + ("" if tp_mlp else "+notpmlp") \
        + ("+wirebf16" if wire_bf16 else "") \
        + ("+padheads" if pad_heads else "")
    tag = f"{arch}__{shape_name}__{mesh_name}__{mode.value}{variant}"
    if not ok:
        art = {"cell": tag, "status": "skipped", "reason": why}
        if save:
            _save(tag, art)
        print(f"[dryrun] {tag}: SKIP ({why})")
        return art

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    jitted, raw_fn, args = build_cell(arch, shape_name, mesh, mode,
                                      remat=remat, tp2d=tp2d, fsdp=fsdp,
                                      tp_mlp=tp_mlp, wire_bf16=wire_bf16,
                                      pad_heads=pad_heads)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    # trip-count-exact per-device costs from the jaxpr (see costs.py)
    from repro.launch.costs import count_costs
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    jaxpr = jax.make_jaxpr(raw_fn)(*args)
    analytic = count_costs(jaxpr, axis_sizes)

    art: Dict[str, Any] = {
        "cell": tag, "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": mode.value, "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", -1.0) if cost else -1.0,
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0)
        if cost else -1.0,
        "collectives": {k: v for k, v in coll.items() if k != "ops"},
        "n_collective_ops": coll["n_ops"],
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "analytic": analytic.as_dict(),
    }
    # roofline terms (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
    n_dev = mesh.devices.size
    if shape.kind == "train":
        model_flops = 6.0 * cfg.active_param_count() * shape.seq_len \
            * shape.global_batch / n_dev
    elif shape.kind == "prefill":
        model_flops = 2.0 * cfg.active_param_count() * shape.seq_len \
            * shape.global_batch / n_dev
    else:
        model_flops = 2.0 * cfg.active_param_count() \
            * shape.global_batch / n_dev
    t_c = analytic.flops / 197e12
    t_m = analytic.dot_bytes / 819e9
    t_l = analytic.link_bytes / 50e9
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])
    # Overlap-aware bounds — the paper's claim made measurable on TPU:
    #   BSP (bulk-synchronous, the paper's MPI baseline): phases serialize,
    #       step >= t_c + t_m + t_l;
    #   LCI (async chunk streams): XLA overlaps independent channels,
    #       step >= max(t_c, t_m, t_l).
    # HBM traffic of the matmuls largely overlaps the MXU (systolic
    # pipelining), so the step-time proxies fold t_m into the compute phase
    # as max(t_c, t_m).
    phase_cm = max(t_c, t_m)
    bsp_bound = phase_cm + t_l
    lci_bound = max(phase_cm, t_l)
    art["roofline"] = {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom[0], "bound_s": dom[1],
        "bsp_bound_s": bsp_bound, "lci_bound_s": lci_bound,
        "overlap_speedup": bsp_bound / max(lci_bound, 1e-12),
        "model_flops_per_device": model_flops,
        "useful_flop_ratio": model_flops / max(analytic.flops, 1.0),
        # fraction of the overlapped step that is pure-MXU time
        "roofline_fraction": (t_c / lci_bound if lci_bound > 0 else 0.0),
    }
    if mem is not None:
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            try:
                art[field] = int(getattr(mem, field))
            except Exception:
                pass
    if save:
        _save(tag, art)
        _save_ops(tag, coll["ops"])
    print(f"[dryrun] {tag}: OK  lower={t_lower:.1f}s compile={t_compile:.1f}s"
          f" flops/dev={art['flops_per_device']:.3g}"
          f" coll_bytes/dev={coll['total_xfer_bytes']:.3g}")
    return art


def _save(tag: str, art: Dict) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, tag + ".json"), "w") as f:
        json.dump(art, f, indent=1)


def _save_ops(tag: str, ops) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, tag + ".ops.json"), "w") as f:
        json.dump(ops, f)


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--mode", default="lci_dedicated")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tp2d", action="store_true",
                    help="2D-TP weight-stationary serving (decode cells)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate weights over data (small models)")
    ap.add_argument("--no-tp-mlp", action="store_true",
                    help="SP-only MLP: replicate d_ff over model")
    ap.add_argument("--wire-bf16", action="store_true",
                    help="bf16 ring accumulators (fp32 local adds)")
    ap.add_argument("--pad-heads", action="store_true",
                    help="pad head counts to shard over the model axis")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell in subprocesses")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells with existing artifacts")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape_name, ok, why in cells():
            tag = (f"{arch}__{shape_name}__{args.mesh}__{args.mode}")
            path = os.path.join(ART_DIR, tag + ".json")
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    st = json.load(f).get("status")
                if st in ("ok", "skipped"):
                    print(f"[dryrun] {tag}: cached ({st})")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--mesh", args.mesh, "--mode", args.mode]
            if args.no_remat:
                cmd.append("--no-remat")
            r = subprocess.run(cmd, cwd=os.getcwd())
            if r.returncode != 0:
                failures.append(tag)
                _save(tag, {"cell": tag, "status": "failed"})
        if failures:
            print(f"[dryrun] FAILURES: {failures}")
            sys.exit(1)
        print("[dryrun] all cells OK")
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    run_cell(args.arch, args.shape, args.mesh == "multi",
             parse_mode(args.mode), remat=not args.no_remat,
             tp2d=args.tp2d, fsdp=not args.no_fsdp,
             tp_mlp=not args.no_tp_mlp, wire_bf16=args.wire_bf16,
             pad_heads=args.pad_heads)


if __name__ == "__main__":
    main()
