"""Training launcher.

CPU-runnable end to end (smoke configs / small device counts), and the
same code path the dry-run proves out for the production meshes.

    # local single-device run of a reduced config
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 50

    # 8 simulated devices, (2,4) mesh, LCI-dedicated collectives
    REPRO_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch olmo-1b --smoke --steps 20 --mesh 2x4 --mode lci_dedicated

Checkpoint/restart: pass --ckpt-dir; rerunning resumes from the last
committed step with exact data replay.
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DEVICES"])

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh, shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.core.modes import CommConfig, parse_mode
from repro.data import SyntheticPipeline, stub_frames, stub_image_embeds
from repro.distributed.comm import Comm, local_comm
from repro.launch.mesh import shard
from repro.models.registry import build_model
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import make_train_step, train_state_init
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x4 => (data=2, model=4); empty = local")
    ap.add_argument("--mode", default="lci_dedicated")
    ap.add_argument("--attr", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="runtime-level attribute override for the comm "
                         "config (repeatable; e.g. --attr n_channels=8 "
                         "— DESIGN.md §12)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--metrics-csv", default="")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt = AdamWConfig(lr=cosine_schedule(args.lr, 10, args.steps))
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)

    pipe = SyntheticPipeline(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch)

    def extras(step):
        out = {}
        if cfg.family == "vlm":
            out["image_embeds"] = stub_image_embeds(
                max(cfg.n_image_tokens, 4), args.batch, cfg.d_model, step
            ).astype(np.float32)
        if cfg.is_encdec:
            t = max(((cfg.n_audio_frames + 15) // 16) * 16, 16)
            out["frames"] = stub_frames(t, args.batch, cfg.d_model, step
                                        ).astype(np.float32)
        return {k: jnp.asarray(v, cfg.dtype) for k, v in out.items()}

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
        from repro.core.attrs import parse_attr_args
        from repro.core.modes import _FIELD_TO_ATTR
        attr_over = parse_attr_args(args.attr)
        fields = {f: attr_over[a] for f, a in _FIELD_TO_ATTR.items()
                  if a in attr_over}
        # the in-graph trainer only consumes CommConfig-mapped attrs;
        # reject the rest rather than silently dropping a valid name
        unused = set(attr_over) - set(_FIELD_TO_ATTR.values())
        if unused:
            raise SystemExit(
                f"--attr {sorted(unused)} are host-runtime attributes; "
                f"the trainer's comm config accepts "
                f"{sorted(_FIELD_TO_ATTR.values())}")
        comm = Comm(CommConfig(**{"mode": parse_mode(args.mode), **fields}),
                    model_axis="model", data_axis="data",
                    fsdp=cfg.fsdp_params)
        step_inner = make_train_step(model, specs, opt, comm)
        pspecs = jax.tree_util.tree_map(lambda sp: sp.pspec(), specs)
        from repro.optim.adamw import OptState
        from repro.train.step import TrainState
        sspecs = TrainState(pspecs, OptState(P(), pspecs, pspecs, pspecs))
        bspec = {"tokens": P("model", "data"), "labels": P("model", "data")}
        if cfg.family == "vlm":
            bspec["image_embeds"] = P(None, "data", None)
        if cfg.is_encdec:
            bspec["frames"] = P("model", "data", None)
        mkeys = ("loss", "ce", "ntok", "aux_lb", "aux_z", "dropped_frac",
                 "grad_norm")
        step_fn = jax.jit(shard_map(
            step_inner, mesh=mesh, in_specs=(sspecs, bspec),
            out_specs=(sspecs, {k: P() for k in mkeys}), check_vma=False),
            donate_argnums=(0,))
    else:
        if args.attr:
            raise SystemExit("--attr tunes the mesh comm config; it needs "
                             "--mesh (single-device runs have no comm)")
        step_fn = jax.jit(make_train_step(model, specs, opt),
                          donate_argnums=(0,))

    def transform(batch, step):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        b.update(extras(step))
        return b

    loop_cfg = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir or None,
        ckpt_every=args.ckpt_every,
        metrics_csv=args.metrics_csv or None)
    t0 = time.time()
    state, hist = train_loop(state, step_fn, pipe, loop_cfg,
                             batch_transform=transform)
    dt = time.time() - t0
    print(f"[train] {args.steps} steps in {dt:.1f}s; "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
