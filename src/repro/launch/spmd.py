"""SPMD launcher — N OS-process ranks over a cross-process transport.

The paper's evaluation compares its multithreaded runtime against the
traditional *multi-process* execution mode (Figures 2/3); this launcher
provides that mode.  It forks N copies of a program (a built-in
message-window demo by default, or any command after ``--``), wires the
bootstrap exchange, and owns teardown:

* **bootstrap** — rank / world-size / session discovery rides the
  environment (``REPRO_SPMD_RANK`` / ``REPRO_SPMD_NRANKS`` /
  ``REPRO_SPMD_SESSION``); the session is a directory both sides derive
  ring-file and socket paths from, so no fd passing or port exchange is
  needed.  :func:`bootstrap` reads it back in the child and returns the
  :class:`SpmdContext`.
* **barrier** — an mmap'd file of per-rank generation counters in the
  session dir (one 64-byte line per rank, single-writer each — the same
  SPSC discipline as the shm rings).  ``ctx.barrier()`` bumps my counter
  and spins (with sleep backoff and a timeout) until every rank reaches
  my generation.
* **teardown** — every child runs in its own process group
  (``start_new_session``); when any rank dies, the launcher SIGTERMs the
  surviving groups, escalates to SIGKILL after a grace period, reaps
  everything, removes the session dir, and exits nonzero.  Joins are
  timeout-bounded — a wedged rank cannot hang the launcher.

Usage::

    python -m repro.launch.spmd --ranks 2 --backend shm
    python -m repro.launch.spmd --ranks 2 --backend shm \\
        --attr fabric_depth=1024 -- python my_rank_program.py
"""
from __future__ import annotations

import argparse
import mmap
import os
import shutil
import signal
import struct
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

RANK_ENV = "REPRO_SPMD_RANK"
NRANKS_ENV = "REPRO_SPMD_NRANKS"
SESSION_ENV = "REPRO_SPMD_SESSION"

_SLOT = 64                       # one cache line per rank counter
_BARRIER_FILE = "barrier"
ALLOW_DIRTY_ENV = "REPRO_SPMD_ALLOW_DIRTY"


def _default_session_root(backend: str) -> str:
    if backend == "shm" and os.path.isdir("/dev/shm"):
        return "/dev/shm"
    return tempfile.gettempdir()


@dataclass
class SpmdContext:
    """One rank's view of the SPMD job (from :func:`bootstrap`)."""
    rank: int
    n_ranks: int
    session: str                 # absolute session-dir path
    _mm: Optional[mmap.mmap] = field(default=None, repr=False)
    _gen: int = 0

    def _barrier_mm(self) -> mmap.mmap:
        if self._mm is None:
            path = os.path.join(self.session, _BARRIER_FILE)
            size = _SLOT * self.n_ranks
            fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o600)
            try:
                os.ftruncate(fd, size)   # idempotent fixed size
                self._mm = mmap.mmap(fd, size)
            finally:
                os.close(fd)
        return self._mm

    def barrier(self, timeout: float = 30.0) -> None:
        """Block until every rank reaches this barrier (generation
        counters: my slot is mine to write, peers' slots mine to read)."""
        mm = self._barrier_mm()
        self._gen += 1
        struct.pack_into("<Q", mm, _SLOT * self.rank, self._gen)
        deadline = time.monotonic() + timeout
        nap = 1e-6
        while True:
            done = all(
                struct.unpack_from("<Q", mm, _SLOT * r)[0] >= self._gen
                for r in range(self.n_ranks))
            if done:
                return
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"rank {self.rank}: barrier generation {self._gen} "
                    f"timed out after {timeout}s")
            time.sleep(nap)
            nap = min(nap * 2, 1e-3)

    def close(self) -> None:
        if self._mm is not None:
            self._mm.close()
            self._mm = None


def bootstrap() -> SpmdContext:
    """Child-side bootstrap: recover rank identity from the launcher's
    environment.  Raises if not running under the launcher."""
    rank = os.environ.get(RANK_ENV)
    if rank is None:
        raise RuntimeError(
            "bootstrap(): not an SPMD child (REPRO_SPMD_RANK unset); "
            "run under `python -m repro.launch.spmd`")
    return SpmdContext(rank=int(rank),
                       n_ranks=int(os.environ[NRANKS_ENV]),
                       session=os.environ[SESSION_ENV])


# ---------------------------------------------------------------------------
# host hygiene: leftovers of dead SPMD jobs skew every timing they share
# a machine with (an orphaned rank spins a core; a stale /dev/shm session
# holds ring memory).  The launcher warns; benchmarks refuse timing rows.
# ---------------------------------------------------------------------------

def _spmd_procs() -> List[Dict]:
    """Live processes bootstrapped by this launcher: any process whose
    environment carries ``REPRO_SPMD_SESSION`` (Linux /proc scan; empty
    elsewhere).  Returns ``{pid, ppid, session}`` per process."""
    procs: List[Dict] = []
    try:
        pids = [int(p) for p in os.listdir("/proc") if p.isdigit()]
    except OSError:
        return procs
    needle = (SESSION_ENV + "=").encode()
    me = os.getpid()
    for pid in pids:
        if pid == me:
            continue
        try:
            with open(f"/proc/{pid}/environ", "rb") as f:
                env = f.read()
        except OSError:
            continue                 # exited, or not ours to read
        session = None
        for chunk in env.split(b"\0"):
            if chunk.startswith(needle):
                session = chunk[len(needle):].decode("utf-8", "replace")
                break
        if session is None:
            continue
        ppid = -1
        try:
            with open(f"/proc/{pid}/stat") as f:
                stat = f.read()
            # comm (field 2) may embed spaces/parens; ppid is the second
            # field after the closing paren
            ppid = int(stat.rsplit(")", 1)[1].split()[1])
        except (OSError, IndexError, ValueError):
            pass
        procs.append({"pid": pid, "ppid": ppid, "session": session})
    return procs


def hygiene_report(roots: Optional[Sequence[str]] = None) -> Dict:
    """Audit the host for leftovers of dead SPMD jobs.

    * **orphans** — rank processes whose launcher died (reparented to
      init, ``ppid == 1``).  They spin in posting/progress loops and eat
      a core each, skewing any wall-clock measured beside them.
    * **stale sessions** — ``repro-spmd-*`` dirs under ``roots``
      (default: /dev/shm and the tempdir) referenced by no live rank;
      teardown was skipped (SIGKILLed launcher), and on /dev/shm the
      ring files pin memory.

    Returns ``{"clean": bool, "orphans": [...], "stale_sessions":
    [...]}``.  Sessions of live non-orphan jobs are neither — a
    concurrent healthy run is not a hygiene problem.
    """
    procs = _spmd_procs()
    orphans = [p for p in procs if p["ppid"] == 1]
    referenced = {os.path.abspath(p["session"]) for p in procs}
    if roots is None:
        roots = ("/dev/shm", tempfile.gettempdir())
    stale: List[str] = []
    seen_roots = set()
    for root in roots:
        root = os.path.abspath(root)
        if root in seen_roots or not os.path.isdir(root):
            continue
        seen_roots.add(root)
        try:
            names = os.listdir(root)
        except OSError:
            continue
        for name in names:
            if not name.startswith("repro-spmd-"):
                continue
            path = os.path.join(root, name)
            if os.path.isdir(path) and path not in referenced:
                stale.append(path)
    return {"clean": not orphans and not stale,
            "orphans": orphans,
            "stale_sessions": sorted(stale)}


def preflight(strict: bool = False,
              roots: Optional[Sequence[str]] = None) -> Dict:
    """Hygiene gate run before launching (or timing).  Prints one line
    per finding; with ``strict`` raises instead of proceeding.  Setting
    ``REPRO_SPMD_ALLOW_DIRTY=1`` downgrades strict to warn (for hosts
    where the leftovers are someone else's and known-idle)."""
    rep = hygiene_report(roots)
    if rep["clean"]:
        return rep
    for p in rep["orphans"]:
        print(f"spmd: orphaned rank pid={p['pid']} "
              f"(launcher dead, session {p['session']})", file=sys.stderr)
    for path in rep["stale_sessions"]:
        print(f"spmd: stale session dir {path} (no live ranks; teardown "
              f"was skipped)", file=sys.stderr)
    if strict and os.environ.get(ALLOW_DIRTY_ENV) != "1":
        raise RuntimeError(
            f"SPMD hygiene preflight failed: {len(rep['orphans'])} "
            f"orphaned rank(s), {len(rep['stale_sessions'])} stale "
            f"session dir(s).  Kill the orphans / remove the dirs, or "
            f"set {ALLOW_DIRTY_ENV}=1 to proceed anyway.")
    return rep


# ---------------------------------------------------------------------------
# launcher (parent side)
# ---------------------------------------------------------------------------

def _child_env(rank: int, n_ranks: int, session: str, backend: str,
               attr_overrides: Dict[str, str]) -> Dict[str, str]:
    env = dict(os.environ)
    env[RANK_ENV] = str(rank)
    env[NRANKS_ENV] = str(n_ranks)
    env[SESSION_ENV] = session
    env["REPRO_ATTR_FABRIC_BACKEND"] = backend
    for name, value in attr_overrides.items():
        env[f"REPRO_ATTR_{name.upper()}"] = value
    return env


def _kill_group(proc: subprocess.Popen, sig: int) -> None:
    try:
        os.killpg(proc.pid, sig)     # child is its own session/group leader
    except (ProcessLookupError, PermissionError):
        pass


def _reap(procs: Sequence[subprocess.Popen], grace: float = 5.0) -> None:
    """Terminate every surviving process group; escalate to SIGKILL."""
    for p in procs:
        if p.poll() is None:
            _kill_group(p, signal.SIGTERM)
    deadline = time.monotonic() + grace
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                pass
    for p in procs:
        if p.poll() is None:
            _kill_group(p, signal.SIGKILL)
            try:
                p.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                pass                 # unkillable (D-state); reported below


def launch(cmd: List[str], n_ranks: int, backend: str = "shm",
           attr_overrides: Optional[Dict[str, str]] = None,
           timeout: float = 120.0, session: Optional[str] = None,
           keep_session: bool = False) -> int:
    """Fork ``cmd`` N times with SPMD bootstrap env; returns the exit
    code (0 only if every rank exited 0 within ``timeout``)."""
    preflight(strict=False)          # warn about leftovers of dead jobs
    owns_session = session is None
    if owns_session:
        session = tempfile.mkdtemp(prefix="repro-spmd-",
                                   dir=_default_session_root(backend))
    session = os.path.abspath(session)
    os.makedirs(session, exist_ok=True)
    procs: List[subprocess.Popen] = []
    code = 0
    try:
        for rank in range(n_ranks):
            procs.append(subprocess.Popen(
                cmd, env=_child_env(rank, n_ranks, session, backend,
                                    attr_overrides or {}),
                start_new_session=True))
        deadline = time.monotonic() + timeout
        live = list(procs)
        while live:
            for p in list(live):
                rc = p.poll()
                if rc is None:
                    continue
                live.remove(p)
                if rc != 0:
                    rank = procs.index(p)
                    print(f"spmd: rank {rank} exited with {rc}; "
                          f"tearing down {len(live)} surviving ranks",
                          file=sys.stderr)
                    code = rc if rc > 0 else 1
                    live = []
                    break
            if time.monotonic() >= deadline:
                print(f"spmd: timeout after {timeout}s; killing all ranks",
                      file=sys.stderr)
                code = code or 124
                break
            if live:
                time.sleep(0.02)
    finally:
        _reap(procs)
        if owns_session and not keep_session:
            shutil.rmtree(session, ignore_errors=True)
    return code


# ---------------------------------------------------------------------------
# built-in demo program: a cross-process message-rate window
# ---------------------------------------------------------------------------

def _run_demo(window: int, iters: int, size: int) -> int:
    """Each rank posts ``window`` eager AMs per iteration to its ring
    neighbor and progresses until the window completes — the message-rate
    kernel cross-process.  Exits nonzero on lost or leaked messages."""
    import numpy as np

    from repro.core import ProcessCluster, post_am

    ctx = bootstrap()
    backend = os.environ.get("REPRO_ATTR_FABRIC_BACKEND", "shm")
    cluster = ProcessCluster(ctx.n_ranks, ctx.rank,
                             fabric_backend=backend, session=ctx.session)
    rt = cluster.runtime
    cq = rt.alloc_cq()
    rt.register_rcomp(cq)        # symmetric alloc: rcomp index 0 everywhere
    peer = (ctx.rank + 1) % ctx.n_ranks
    buf = np.arange(size, dtype=np.uint8)
    got = 0

    # a rank must never outlive its job: if the launcher is SIGKILLed its
    # teardown cannot run, and a peer-less rank would spin in the posting
    # retry loop forever.  Orphan check (reparented => launcher died) plus
    # a hard wall-clock bound make every loop below self-terminating.
    ppid0 = os.getppid()
    hard_deadline = time.monotonic() + float(
        os.environ.get("REPRO_SPMD_DEADLINE", "600"))

    def check_alive() -> None:
        if os.getppid() != ppid0:
            print(f"spmd-demo rank {ctx.rank}: launcher died; exiting",
                  file=sys.stderr)
            os._exit(2)
        if time.monotonic() > hard_deadline:
            print(f"spmd-demo rank {ctx.rank}: hard deadline exceeded",
                  file=sys.stderr)
            os._exit(3)

    ctx.barrier()
    t0 = time.perf_counter()
    for _ in range(iters):
        posted = 0
        while posted < window:
            st = post_am(rt, peer, buf, remote_comp=0)
            if not st.is_retry():
                posted += 1
            else:
                check_alive()
                rt.progress()
            while cq.pop().is_done():
                got += 1
        # finish the iteration's own sends; peer deliveries keep landing
        # (ring back-pressure — not peer lockstep — is the flow control)
        spin_deadline = time.monotonic() + 10.0
        while rt.pending_ops and time.monotonic() < spin_deadline:
            check_alive()
            rt.progress()
            while cq.pop().is_done():
                got += 1
    # drain until every rank's deliveries arrived (peer may lag)
    expect = window * iters
    spin_deadline = time.monotonic() + 30.0
    while got < expect and time.monotonic() < spin_deadline:
        check_alive()
        rt.progress()
        while cq.pop().is_done():
            got += 1
    elapsed = time.perf_counter() - t0
    ctx.barrier()
    lost = expect - got
    leaked = cluster.fabric.in_flight()
    rate = expect / elapsed if elapsed > 0 else float("inf")
    print(f"spmd-demo rank {ctx.rank}: {expect} msgs in {elapsed:.3f}s "
          f"({rate:,.0f} msg/s) lost={lost} leaked={leaked}")
    cluster.close()
    ctx.close()
    return 0 if lost == 0 and leaked == 0 else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="SPMD launcher: N OS-process ranks over a "
                    "cross-process transport backend")
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--backend", default="shm",
                    choices=("shm", "socket"))
    ap.add_argument("--attr", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="attr override exported as REPRO_ATTR_* to every "
                         "rank (repeatable)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="wall-clock bound; past it every rank is killed")
    ap.add_argument("--window", type=int, default=64,
                    help="demo: messages per completion window")
    ap.add_argument("--iters", type=int, default=50,
                    help="demo: windows per rank")
    ap.add_argument("--size", type=int, default=64,
                    help="demo: payload bytes")
    ap.add_argument("cmd", nargs="*",
                    help="rank program after `--` (default: built-in "
                         "message-window demo)")
    args = ap.parse_args(argv)

    if os.environ.get(RANK_ENV) is not None and not args.cmd:
        # child re-entry of the built-in demo
        return _run_demo(args.window, args.iters, args.size)

    overrides = {}
    for item in args.attr:
        name, eq, value = item.partition("=")
        if not eq:
            ap.error(f"--attr expects NAME=VALUE, got {item!r}")
        overrides[name] = value
    cmd = args.cmd or [sys.executable, "-m", "repro.launch.spmd",
                       "--ranks", str(args.ranks),
                       "--window", str(args.window),
                       "--iters", str(args.iters),
                       "--size", str(args.size)]
    return launch(cmd, args.ranks, backend=args.backend,
                  attr_overrides=overrides, timeout=args.timeout)


if __name__ == "__main__":
    sys.exit(main())
