"""SPMD launcher — N OS-process ranks over a cross-process transport.

The paper's evaluation compares its multithreaded runtime against the
traditional *multi-process* execution mode (Figures 2/3); this launcher
provides that mode.  It forks N copies of a program (a built-in
message-window demo by default, or any command after ``--``), wires the
bootstrap exchange, and owns teardown:

* **bootstrap** — rank / world-size / session discovery rides the
  environment (``REPRO_SPMD_RANK`` / ``REPRO_SPMD_NRANKS`` /
  ``REPRO_SPMD_SESSION``); the session is a directory both sides derive
  ring-file and socket paths from, so no fd passing or port exchange is
  needed.  :func:`bootstrap` reads it back in the child and returns the
  :class:`SpmdContext`.
* **barrier** — an mmap'd file of per-rank generation counters in the
  session dir (one 64-byte line per rank, single-writer each — the same
  SPSC discipline as the shm rings).  ``ctx.barrier()`` bumps my counter
  and spins (with sleep backoff and a timeout) until every rank reaches
  my generation.
* **teardown** — every child runs in its own process group
  (``start_new_session``); when any rank dies, the launcher SIGTERMs the
  surviving groups, escalates to SIGKILL after a grace period, reaps
  everything, removes the session dir, and exits nonzero.  Joins are
  timeout-bounded — a wedged rank cannot hang the launcher.

Usage::

    python -m repro.launch.spmd --ranks 2 --backend shm
    python -m repro.launch.spmd --ranks 2 --backend shm \\
        --attr fabric_depth=1024 -- python my_rank_program.py
"""
from __future__ import annotations

import argparse
import mmap
import os
import shutil
import signal
import struct
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

RANK_ENV = "REPRO_SPMD_RANK"
NRANKS_ENV = "REPRO_SPMD_NRANKS"
SESSION_ENV = "REPRO_SPMD_SESSION"

_SLOT = 64                       # one cache line per rank counter
_BARRIER_FILE = "barrier"
_HB_FILE = "heartbeat"
_HB = struct.Struct("<Qd")       # [beat count][wall-clock stamp]
ALLOW_DIRTY_ENV = "REPRO_SPMD_ALLOW_DIRTY"


def _default_session_root(backend: str) -> str:
    if backend == "shm" and os.path.isdir("/dev/shm"):
        return "/dev/shm"
    return tempfile.gettempdir()


@dataclass
class SpmdContext:
    """One rank's view of the SPMD job (from :func:`bootstrap`)."""
    rank: int
    n_ranks: int
    session: str                 # absolute session-dir path
    _mm: Optional[mmap.mmap] = field(default=None, repr=False)
    _gen: int = 0
    _hb: Optional[mmap.mmap] = field(default=None, repr=False)
    _beats: int = 0

    def _slot_mm(self, attr: str, filename: str) -> mmap.mmap:
        if getattr(self, attr) is None:
            path = os.path.join(self.session, filename)
            size = _SLOT * self.n_ranks
            fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o600)
            try:
                os.ftruncate(fd, size)   # idempotent fixed size
                setattr(self, attr, mmap.mmap(fd, size))
            finally:
                os.close(fd)
        return getattr(self, attr)

    def _barrier_mm(self) -> mmap.mmap:
        return self._slot_mm("_mm", _BARRIER_FILE)

    def barrier(self, timeout: float = 30.0) -> None:
        """Block until every rank reaches this barrier (generation
        counters: my slot is mine to write, peers' slots mine to read)."""
        mm = self._barrier_mm()
        self._gen += 1
        struct.pack_into("<Q", mm, _SLOT * self.rank, self._gen)
        deadline = time.monotonic() + timeout
        nap = 1e-6
        while True:
            done = all(
                struct.unpack_from("<Q", mm, _SLOT * r)[0] >= self._gen
                for r in range(self.n_ranks))
            if done:
                return
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"rank {self.rank}: barrier generation {self._gen} "
                    f"timed out after {timeout}s")
            time.sleep(nap)
            nap = min(nap * 2, 1e-3)

    # -- heartbeats: the failure-detector input (DESIGN.md §16) ---------
    # Same single-writer slot discipline as the barrier: my 64-byte slot
    # carries [u64 beat count][f64 wall-clock stamp]; peers only read it.
    # The launcher reads the same file to time chaos kills, and survivors
    # read it to declare a silent rank dead.

    def _hb_mm(self) -> mmap.mmap:
        return self._slot_mm("_hb", _HB_FILE)

    def heartbeat(self) -> int:
        """Publish liveness: bump my beat count, stamp the wall clock."""
        mm = self._hb_mm()
        self._beats += 1
        _HB.pack_into(mm, _SLOT * self.rank, self._beats, time.time())
        return self._beats

    def peer_heartbeats(self) -> List[tuple]:
        """``[(beat_count, last_stamp), ...]`` indexed by rank."""
        mm = self._hb_mm()
        return [_HB.unpack_from(mm, _SLOT * r) for r in range(self.n_ranks)]

    def dead_ranks(self, timeout: float = 2.0) -> List[int]:
        """Ranks that heartbeat at least once, then went silent for more
        than ``timeout`` seconds.  A rank that never beat is still
        booting, not dead — liveness starts at the first beat."""
        now = time.time()
        return [r for r, (count, t) in enumerate(self.peer_heartbeats())
                if r != self.rank and count > 0 and now - t > timeout]

    def close(self) -> None:
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        if self._hb is not None:
            self._hb.close()
            self._hb = None


def bootstrap() -> SpmdContext:
    """Child-side bootstrap: recover rank identity from the launcher's
    environment.  Raises if not running under the launcher."""
    rank = os.environ.get(RANK_ENV)
    if rank is None:
        raise RuntimeError(
            "bootstrap(): not an SPMD child (REPRO_SPMD_RANK unset); "
            "run under `python -m repro.launch.spmd`")
    return SpmdContext(rank=int(rank),
                       n_ranks=int(os.environ[NRANKS_ENV]),
                       session=os.environ[SESSION_ENV])


# ---------------------------------------------------------------------------
# host hygiene: leftovers of dead SPMD jobs skew every timing they share
# a machine with (an orphaned rank spins a core; a stale /dev/shm session
# holds ring memory).  The launcher warns; benchmarks refuse timing rows.
# ---------------------------------------------------------------------------

def _spmd_procs() -> List[Dict]:
    """Live processes bootstrapped by this launcher: any process whose
    environment carries ``REPRO_SPMD_SESSION`` (Linux /proc scan; empty
    elsewhere).  Returns ``{pid, ppid, session}`` per process."""
    procs: List[Dict] = []
    try:
        pids = [int(p) for p in os.listdir("/proc") if p.isdigit()]
    except OSError:
        return procs
    needle = (SESSION_ENV + "=").encode()
    me = os.getpid()
    for pid in pids:
        if pid == me:
            continue
        try:
            with open(f"/proc/{pid}/environ", "rb") as f:
                env = f.read()
        except OSError:
            continue                 # exited, or not ours to read
        session = None
        for chunk in env.split(b"\0"):
            if chunk.startswith(needle):
                session = chunk[len(needle):].decode("utf-8", "replace")
                break
        if session is None:
            continue
        ppid = -1
        try:
            with open(f"/proc/{pid}/stat") as f:
                stat = f.read()
            # comm (field 2) may embed spaces/parens; ppid is the second
            # field after the closing paren
            ppid = int(stat.rsplit(")", 1)[1].split()[1])
        except (OSError, IndexError, ValueError):
            pass
        procs.append({"pid": pid, "ppid": ppid, "session": session})
    return procs


def hygiene_report(roots: Optional[Sequence[str]] = None) -> Dict:
    """Audit the host for leftovers of dead SPMD jobs.

    * **orphans** — rank processes whose launcher died (reparented to
      init, ``ppid == 1``).  They spin in posting/progress loops and eat
      a core each, skewing any wall-clock measured beside them.
    * **stale sessions** — ``repro-spmd-*`` dirs under ``roots``
      (default: /dev/shm and the tempdir) referenced by no live rank;
      teardown was skipped (SIGKILLed launcher), and on /dev/shm the
      ring files pin memory.

    Returns ``{"clean": bool, "orphans": [...], "stale_sessions":
    [...]}``.  Sessions of live non-orphan jobs are neither — a
    concurrent healthy run is not a hygiene problem.
    """
    procs = _spmd_procs()
    orphans = [p for p in procs if p["ppid"] == 1]
    referenced = {os.path.abspath(p["session"]) for p in procs}
    if roots is None:
        roots = ("/dev/shm", tempfile.gettempdir())
    stale: List[str] = []
    seen_roots = set()
    for root in roots:
        root = os.path.abspath(root)
        if root in seen_roots or not os.path.isdir(root):
            continue
        seen_roots.add(root)
        try:
            names = os.listdir(root)
        except OSError:
            continue
        for name in names:
            if not name.startswith("repro-spmd-"):
                continue
            path = os.path.join(root, name)
            if os.path.isdir(path) and path not in referenced:
                stale.append(path)
    return {"clean": not orphans and not stale,
            "orphans": orphans,
            "stale_sessions": sorted(stale)}


def preflight(strict: bool = False,
              roots: Optional[Sequence[str]] = None) -> Dict:
    """Hygiene gate run before launching (or timing).  Prints one line
    per finding; with ``strict`` raises instead of proceeding.  Setting
    ``REPRO_SPMD_ALLOW_DIRTY=1`` downgrades strict to warn (for hosts
    where the leftovers are someone else's and known-idle)."""
    rep = hygiene_report(roots)
    if rep["clean"]:
        return rep
    for p in rep["orphans"]:
        print(f"spmd: orphaned rank pid={p['pid']} "
              f"(launcher dead, session {p['session']})", file=sys.stderr)
    for path in rep["stale_sessions"]:
        print(f"spmd: stale session dir {path} (no live ranks; teardown "
              f"was skipped)", file=sys.stderr)
    if strict and os.environ.get(ALLOW_DIRTY_ENV) != "1":
        raise RuntimeError(
            f"SPMD hygiene preflight failed: {len(rep['orphans'])} "
            f"orphaned rank(s), {len(rep['stale_sessions'])} stale "
            f"session dir(s).  Kill the orphans / remove the dirs, or "
            f"set {ALLOW_DIRTY_ENV}=1 to proceed anyway.")
    return rep


# ---------------------------------------------------------------------------
# launcher (parent side)
# ---------------------------------------------------------------------------

def _child_env(rank: int, n_ranks: int, session: str, backend: str,
               attr_overrides: Dict[str, str]) -> Dict[str, str]:
    env = dict(os.environ)
    env[RANK_ENV] = str(rank)
    env[NRANKS_ENV] = str(n_ranks)
    env[SESSION_ENV] = session
    env["REPRO_ATTR_FABRIC_BACKEND"] = backend
    for name, value in attr_overrides.items():
        env[f"REPRO_ATTR_{name.upper()}"] = value
    return env


def _kill_group(proc: subprocess.Popen, sig: int) -> None:
    try:
        os.killpg(proc.pid, sig)     # child is its own session/group leader
    except (ProcessLookupError, PermissionError):
        pass


def _reap(procs: Sequence[subprocess.Popen], grace: float = 5.0) -> None:
    """Terminate every surviving process group; escalate to SIGKILL."""
    for p in procs:
        if p.poll() is None:
            _kill_group(p, signal.SIGTERM)
    deadline = time.monotonic() + grace
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                pass
    for p in procs:
        if p.poll() is None:
            _kill_group(p, signal.SIGKILL)
            try:
                p.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                pass                 # unkillable (D-state); reported below


def _all_beating(session: str, n_ranks: int) -> bool:
    """Launcher-side read of the heartbeat file: every rank beat >= once."""
    path = os.path.join(session, _HB_FILE)
    try:
        with open(path, "rb") as f:
            raw = f.read(_SLOT * n_ranks)
    except OSError:
        return False
    if len(raw) < _SLOT * n_ranks:
        return False
    return all(_HB.unpack_from(raw, _SLOT * r)[0] > 0
               for r in range(n_ranks))


def launch(cmd: List[str], n_ranks: int, backend: str = "shm",
           attr_overrides: Optional[Dict[str, str]] = None,
           timeout: float = 120.0, session: Optional[str] = None,
           keep_session: bool = False, kill_rank: Optional[int] = None,
           kill_after: float = 1.0) -> int:
    """Fork ``cmd`` N times with SPMD bootstrap env; returns the exit
    code (0 only if every rank exited 0 within ``timeout``).

    ``kill_rank`` arms the chaos kill: once every rank has heartbeat at
    least once, wait ``kill_after`` seconds and SIGKILL that rank's
    process group.  Its death is then *expected* — the launcher does not
    tear the survivors down, and success means every OTHER rank exited 0
    (the rank-death recovery contract, DESIGN.md §16).
    """
    preflight(strict=False)          # warn about leftovers of dead jobs
    if kill_rank is not None and not 0 <= kill_rank < n_ranks:
        raise ValueError(f"kill_rank {kill_rank} out of range")
    owns_session = session is None
    if owns_session:
        session = tempfile.mkdtemp(prefix="repro-spmd-",
                                   dir=_default_session_root(backend))
    session = os.path.abspath(session)
    os.makedirs(session, exist_ok=True)
    procs: List[subprocess.Popen] = []
    code = 0
    try:
        for rank in range(n_ranks):
            procs.append(subprocess.Popen(
                cmd, env=_child_env(rank, n_ranks, session, backend,
                                    attr_overrides or {}),
                start_new_session=True))
        deadline = time.monotonic() + timeout
        live = list(procs)
        victim = procs[kill_rank] if kill_rank is not None else None
        killed = False
        all_alive_at: Optional[float] = None
        while live:
            if victim is not None and not killed:
                if all_alive_at is None and _all_beating(session, n_ranks):
                    all_alive_at = time.monotonic()
                if all_alive_at is not None and \
                        time.monotonic() >= all_alive_at + kill_after:
                    print(f"spmd: chaos-kill SIGKILL rank {kill_rank}",
                          file=sys.stderr)
                    _kill_group(victim, signal.SIGKILL)
                    killed = True
            for p in list(live):
                rc = p.poll()
                if rc is None:
                    continue
                live.remove(p)
                if p is victim and killed:
                    continue         # expected death; survivors run on
                if rc != 0:
                    rank = procs.index(p)
                    print(f"spmd: rank {rank} exited with {rc}; "
                          f"tearing down {len(live)} surviving ranks",
                          file=sys.stderr)
                    code = rc if rc > 0 else 1
                    live = []
                    break
            if time.monotonic() >= deadline:
                print(f"spmd: timeout after {timeout}s; killing all ranks",
                      file=sys.stderr)
                code = code or 124
                break
            if live:
                time.sleep(0.02)
        if victim is not None and not killed and code == 0:
            # victim finished before the kill ever armed/fired — the
            # chaos run proved nothing; fail loudly rather than greenly
            print("spmd: chaos-kill never fired (job too short?)",
                  file=sys.stderr)
            code = 1
    finally:
        _reap(procs)
        if owns_session and not keep_session:
            shutil.rmtree(session, ignore_errors=True)
    return code


# ---------------------------------------------------------------------------
# built-in demo program: a cross-process message-rate window
# ---------------------------------------------------------------------------

def _run_demo(window: int, iters: int, size: int) -> int:
    """Each rank posts ``window`` eager AMs per iteration to its ring
    neighbor and progresses until the window completes — the message-rate
    kernel cross-process.  Exits nonzero on lost or leaked messages."""
    import numpy as np

    from repro.core import ProcessCluster, post_am

    ctx = bootstrap()
    backend = os.environ.get("REPRO_ATTR_FABRIC_BACKEND", "shm")
    cluster = ProcessCluster(ctx.n_ranks, ctx.rank,
                             fabric_backend=backend, session=ctx.session)
    rt = cluster.runtime
    cq = rt.alloc_cq()
    rt.register_rcomp(cq)        # symmetric alloc: rcomp index 0 everywhere
    peer = (ctx.rank + 1) % ctx.n_ranks
    buf = np.arange(size, dtype=np.uint8)
    got = 0

    # a rank must never outlive its job: if the launcher is SIGKILLed its
    # teardown cannot run, and a peer-less rank would spin in the posting
    # retry loop forever.  Orphan check (reparented => launcher died) plus
    # a hard wall-clock bound make every loop below self-terminating.
    ppid0 = os.getppid()
    hard_deadline = time.monotonic() + float(
        os.environ.get("REPRO_SPMD_DEADLINE", "600"))

    def check_alive() -> None:
        if os.getppid() != ppid0:
            print(f"spmd-demo rank {ctx.rank}: launcher died; exiting",
                  file=sys.stderr)
            os._exit(2)
        if time.monotonic() > hard_deadline:
            print(f"spmd-demo rank {ctx.rank}: hard deadline exceeded",
                  file=sys.stderr)
            os._exit(3)

    ctx.barrier()
    t0 = time.perf_counter()
    for _ in range(iters):
        posted = 0
        while posted < window:
            st = post_am(rt, peer, buf, remote_comp=0)
            if not st.is_retry():
                posted += 1
            else:
                check_alive()
                rt.progress()
            while cq.pop().is_done():
                got += 1
        # finish the iteration's own sends; peer deliveries keep landing
        # (ring back-pressure — not peer lockstep — is the flow control)
        spin_deadline = time.monotonic() + 10.0
        while rt.pending_ops and time.monotonic() < spin_deadline:
            check_alive()
            rt.progress()
            while cq.pop().is_done():
                got += 1
    # drain until every rank's deliveries arrived (peer may lag)
    expect = window * iters
    spin_deadline = time.monotonic() + 30.0
    while got < expect and time.monotonic() < spin_deadline:
        check_alive()
        rt.progress()
        while cq.pop().is_done():
            got += 1
    elapsed = time.perf_counter() - t0
    # cooldown: our receives being done says nothing about our *sends* —
    # under chaos a dropped message to the peer is only retransmitted by
    # OUR progress calls, so keep driving until the peer acked everything
    # (otherwise the peer spins out its drain deadline and reports lost)
    spin_deadline = time.monotonic() + 30.0
    while rt.rel is not None and rt.rel.busy() \
            and time.monotonic() < spin_deadline:
        check_alive()
        rt.progress()
        while cq.pop().is_done():
            got += 1
    ctx.barrier()
    lost = expect - got
    leaked = cluster.fabric.in_flight()
    rate = expect / elapsed if elapsed > 0 else float("inf")
    print(f"spmd-demo rank {ctx.rank}: {expect} msgs in {elapsed:.3f}s "
          f"({rate:,.0f} msg/s) lost={lost} leaked={leaked}")
    cluster.close()
    ctx.close()
    return 0 if lost == 0 and leaked == 0 else 1


def _run_chaos_demo(size: int, kill_rank: int, hb_timeout: float) -> int:
    """Rank-death recovery end to end (DESIGN.md §16): every rank streams
    eager AMs to its ring neighbor and heartbeats; the launcher SIGKILLs
    ``kill_rank`` mid-stream.  Survivors detect the silence, mark the
    peer dead (outstanding posts complete as ERR_PEER_DEAD — no hang),
    shrink the mesh to the largest compatible survivor shape, and
    restore the step-0 checkpoint resharded onto it.  Survivor exit 0 is
    the proof; the launcher treats the victim's death as expected."""
    import numpy as np

    from repro.core import ProcessCluster, post_am
    from repro.core.status import ErrorCode

    ctx = bootstrap()
    backend = os.environ.get("REPRO_ATTR_FABRIC_BACKEND", "shm")
    cluster = ProcessCluster(ctx.n_ranks, ctx.rank,
                             fabric_backend=backend, session=ctx.session)
    rt = cluster.runtime
    cq = rt.alloc_cq()
    rt.register_rcomp(cq)        # symmetric alloc: rcomp index 0 everywhere
    scq = rt.alloc_cq()          # send-side completions (done / err)
    peer = (ctx.rank + 1) % ctx.n_ranks
    buf = np.arange(size, dtype=np.uint8)

    # the recovery anchor: rank 0 commits a step-0 checkpoint every
    # survivor can restore from (atomic rename — a crash cannot corrupt it)
    ckpt_dir = os.path.join(ctx.session, "ckpt")
    state = {"w": np.arange(64, dtype=np.float64),
             "step": np.zeros((), dtype=np.int64)}
    if ctx.rank == 0:
        from repro.checkpoint import save_sync
        save_sync(ckpt_dir, 0, state, meta={"world": ctx.n_ranks})

    ppid0 = os.getppid()
    hard_deadline = time.monotonic() + float(
        os.environ.get("REPRO_SPMD_DEADLINE", "120"))

    def check_alive() -> None:
        if os.getppid() != ppid0:
            print(f"spmd-chaos rank {ctx.rank}: launcher died; exiting",
                  file=sys.stderr)
            os._exit(2)
        if time.monotonic() > hard_deadline:
            print(f"spmd-chaos rank {ctx.rank}: hard deadline exceeded",
                  file=sys.stderr)
            os._exit(3)

    counts = {"done": 0, "delivered": 0, "peer_dead": 0, "timeout": 0,
              "other": 0}

    def drain() -> None:
        for q, done_key in ((scq, "done"), (cq, "delivered")):
            while True:
                st = q.pop()
                if st.is_done():
                    counts[done_key] += 1
                elif st.is_err():
                    if st.code == ErrorCode.ERR_PEER_DEAD:
                        counts["peer_dead"] += 1
                    elif st.code == ErrorCode.ERR_TIMEOUT:
                        counts["timeout"] += 1
                    else:
                        counts["other"] += 1
                else:
                    break            # empty (retry status)

    ctx.heartbeat()
    ctx.barrier()                    # checkpoint committed, all booted

    dead: List[int] = []
    t0 = time.monotonic()
    while not dead:
        check_alive()
        ctx.heartbeat()
        dead = ctx.dead_ranks(hb_timeout)
        st = post_am(rt, peer, buf, local_comp=scq, remote_comp=0)
        if st.is_retry():
            rt.progress()
        drain()

    t_detect = time.monotonic()
    for r in dead:
        rt.mark_peer_dead(r)
    print(f"spmd-chaos rank {ctx.rank}: peer(s) {dead} dead "
          f"(silent > {hb_timeout}s at t+{t_detect - t0:.2f}s)",
          file=sys.stderr)

    # every outstanding post must complete (ERR_PEER_DEAD), not hang
    spin_deadline = time.monotonic() + 10.0
    while rt.pending_ops and time.monotonic() < spin_deadline:
        check_alive()
        rt.progress()
        drain()
    drain()
    hung = len(rt.pending_ops)

    # elastic recovery: largest compatible survivor mesh + resharded
    # restore of the pre-fault checkpoint
    import jax

    from repro.checkpoint import restore_resharded
    from repro.configs.gemma3_1b import SMOKE
    from repro.distributed.elastic import shrink_mesh

    new_shape = shrink_mesh((ctx.n_ranks, 1),
                            len(dead) / ctx.n_ranks, SMOKE)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices("cpu")[0])
    like = {"w": np.zeros(64, np.float64),
            "step": np.zeros((), dtype=np.int64)}
    restored, manifest = restore_resharded(
        ckpt_dir, like, jax.tree_util.tree_map(lambda _: sharding, like))
    ok_restore = (manifest["step"] == 0
                  and int(np.asarray(restored["step"])) == 0
                  and np.asarray(restored["w"]).sum() == state["w"].sum())
    recovery_ms = (time.monotonic() - t_detect) * 1e3

    print(f"spmd-chaos rank {ctx.rank}: recovered in {recovery_ms:.0f}ms "
          f"new_mesh={new_shape} restored_step={manifest['step']} "
          f"sent={counts['done']} delivered={counts['delivered']} "
          f"peer_dead={counts['peer_dead']} timeout={counts['timeout']} "
          f"other={counts['other']} hung={hung}")
    rel = rt.rel.counters() if rt.rel is not None else {}
    if rel:
        print(f"spmd-chaos rank {ctx.rank}: rel retransmits="
              f"{rel.get('retransmits')} expired_peer_dead="
              f"{rel.get('expired_peer_dead')}")
    cluster.close()
    ctx.close()
    ok = (hung == 0 and counts["other"] == 0 and ok_restore
          and (peer not in dead or counts["peer_dead"] > 0))
    return 0 if ok else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="SPMD launcher: N OS-process ranks over a "
                    "cross-process transport backend")
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--backend", default="shm",
                    choices=("shm", "socket"))
    ap.add_argument("--attr", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="attr override exported as REPRO_ATTR_* to every "
                         "rank (repeatable)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="wall-clock bound; past it every rank is killed")
    ap.add_argument("--window", type=int, default=64,
                    help="demo: messages per completion window")
    ap.add_argument("--iters", type=int, default=50,
                    help="demo: windows per rank")
    ap.add_argument("--size", type=int, default=64,
                    help="demo: payload bytes")
    ap.add_argument("--chaos-kill", type=int, default=None, metavar="RANK",
                    help="chaos demo: SIGKILL this rank once traffic "
                         "flows; survivors must recover and exit 0")
    ap.add_argument("--kill-after", type=float, default=1.0,
                    help="chaos demo: seconds between all-ranks-beating "
                         "and the SIGKILL")
    ap.add_argument("--hb-timeout", type=float, default=1.0,
                    help="chaos demo: heartbeat silence that declares a "
                         "rank dead")
    ap.add_argument("cmd", nargs="*",
                    help="rank program after `--` (default: built-in "
                         "message-window demo)")
    args = ap.parse_args(argv)

    if os.environ.get(RANK_ENV) is not None and not args.cmd:
        # child re-entry of a built-in demo
        if args.chaos_kill is not None:
            return _run_chaos_demo(args.size, args.chaos_kill,
                                   args.hb_timeout)
        return _run_demo(args.window, args.iters, args.size)

    overrides = {}
    for item in args.attr:
        name, eq, value = item.partition("=")
        if not eq:
            ap.error(f"--attr expects NAME=VALUE, got {item!r}")
        overrides[name] = value
    cmd = args.cmd or [sys.executable, "-m", "repro.launch.spmd",
                       "--ranks", str(args.ranks),
                       "--window", str(args.window),
                       "--iters", str(args.iters),
                       "--size", str(args.size)]
    if args.chaos_kill is not None:
        if not args.cmd:
            cmd += ["--chaos-kill", str(args.chaos_kill),
                    "--hb-timeout", str(args.hb_timeout)]
            # survivors prove ERR_PEER_DEAD, not retry exhaustion: keep
            # unacked entries alive until the failure detector fires
            overrides.setdefault("reliability", "on")
            overrides.setdefault("retry_limit", "1000000")
            # inject-class sends never signal local comps (paper §3.2.5);
            # the demo counts send completions, so force bufcopy class
            overrides.setdefault("eager_max_bytes", "0")
        return launch(cmd, args.ranks, backend=args.backend,
                      attr_overrides=overrides, timeout=args.timeout,
                      kill_rank=args.chaos_kill,
                      kill_after=args.kill_after)
    return launch(cmd, args.ranks, backend=args.backend,
                  attr_overrides=overrides, timeout=args.timeout)


if __name__ == "__main__":
    sys.exit(main())
