"""Jaxpr-walking cost model — trip-count-exact FLOPs / bytes / collectives.

XLA's ``compiled.cost_analysis()`` counts a ``scan`` (while-loop) body
ONCE, so any scan-rolled program (all of ours: layer stacks, flash blocks,
CE chunks) is undercounted by the trip count.  This walker recurses the
jaxpr instead, multiplying by static scan lengths — exact for this
framework's programs (no data-dependent while loops on the hot path).

Per-device accounting (walk the jaxpr of the *shard_mapped* function:
inner shapes are local shapes):

* ``flops``            — 2·batch·m·n·k per dot_general (einsums included);
* ``dot_bytes``        — Σ (lhs+rhs+out) bytes of every dot: the HBM-traffic
  model for a well-fused program (weights streamed per scan iteration are
  dot operands, so FSDP/TP weight streaming is captured exactly);
* ``collective``       — per-kind transferred bytes using ring algorithm
  models; ppermute bytes split by ring *direction* (the two ICI links),
  with per-direction serial step counts (latency-chain proxy).

Ring models (bytes one device puts on a link, per op):
  ppermute: |operand|;  all_gather(tiled): |in|·(P-1);
  reduce_scatter: |out|·(P-1);  psum: 2·|x|·(P-1)/P;
  all_to_all: |x|·(P-1)/P;  pmax/pmin: like psum.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    ppermute_fwd_bytes: float = 0.0
    ppermute_bwd_bytes: float = 0.0
    ppermute_fwd_steps: float = 0.0
    ppermute_bwd_steps: float = 0.0
    unknown_while: int = 0

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def link_bytes(self) -> float:
        """Worst single-link traffic: counter-rotating rings use both
        directions concurrently, so the busier direction + everything
        that is not direction-split."""
        other = self.total_coll_bytes - self.ppermute_fwd_bytes \
            - self.ppermute_bwd_bytes
        return max(self.ppermute_fwd_bytes, self.ppermute_bwd_bytes) + other

    def as_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "dot_bytes": self.dot_bytes,
            "coll_bytes_by_kind": dict(self.coll_bytes),
            "coll_bytes_total": self.total_coll_bytes,
            "coll_link_bytes": self.link_bytes,
            "ppermute_fwd_bytes": self.ppermute_fwd_bytes,
            "ppermute_bwd_bytes": self.ppermute_bwd_bytes,
            "ppermute_fwd_steps": self.ppermute_fwd_steps,
            "ppermute_bwd_steps": self.ppermute_bwd_steps,
            "unknown_while": self.unknown_while,
        }


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _axes_prod(axes, axis_sizes: Dict[str, int]) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(axis_sizes.get(a, 1) for a in axes)


def _sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        if isinstance(v, (list, tuple)):
            for x in v:
                if hasattr(x, "eqns"):
                    yield x
                elif hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                    yield x.jaxpr
        elif hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            yield v.jaxpr


def count_costs(jaxpr, axis_sizes: Dict[str, int],
                costs: Optional[Costs] = None, mult: float = 1.0) -> Costs:
    """Walk a (Closed)Jaxpr; multiply scan bodies by their length."""
    c = costs if costs is not None else Costs()
    jx = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr

    for eqn in jx.eqns:
        name = eqn.primitive.name
        p = eqn.params

        if name == "dot_general":
            (lc, rc), (lb, rb) = p["dimension_numbers"]
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            contract = math.prod(lhs.shape[i] for i in lc) or 1
            batch = math.prod(lhs.shape[i] for i in lb) or 1
            lfree = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                              if i not in lc and i not in lb) or 1
            rfree = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                              if i not in rc and i not in rb) or 1
            c.flops += mult * 2.0 * batch * lfree * rfree * contract
            c.dot_bytes += mult * (_nbytes(lhs) + _nbytes(rhs)
                                   + sum(_nbytes(v.aval)
                                         for v in eqn.outvars))
            continue

        if name == "ppermute":
            b = _nbytes(eqn.invars[0].aval) * mult
            perm = p.get("perm", ())
            fwd = True
            if perm:
                src, dst = perm[0]
                n = max(max(s, d) for s, d in perm) + 1
                fwd = dst == (src + 1) % n
            c.coll_bytes["ppermute"] = c.coll_bytes.get("ppermute", 0.0) + b
            if fwd:
                c.ppermute_fwd_bytes += b
                c.ppermute_fwd_steps += mult
            else:
                c.ppermute_bwd_bytes += b
                c.ppermute_bwd_steps += mult
            continue

        if name in ("psum", "psum_invariant", "pmax", "pmin"):
            pp = _axes_prod(p.get("axes", ()), axis_sizes)
            b = sum(_nbytes(v.aval) for v in eqn.invars)
            xfer = 2.0 * b * (pp - 1) / max(pp, 1) * mult
            key = "psum" if name.startswith("psum") else name
            c.coll_bytes[key] = c.coll_bytes.get(key, 0.0) + xfer
            continue

        if name == "all_gather":
            pp = p.get("axis_size", _axes_prod(p.get("axis_name", ()),
                                               axis_sizes))
            b = _nbytes(eqn.invars[0].aval)
            xfer = b * (pp - 1) * mult
            c.coll_bytes["all_gather"] = \
                c.coll_bytes.get("all_gather", 0.0) + xfer
            continue

        if name == "reduce_scatter":
            pp = p.get("axis_size", 1)
            b = sum(_nbytes(v.aval) for v in eqn.outvars)
            xfer = b * (pp - 1) * mult
            c.coll_bytes["reduce_scatter"] = \
                c.coll_bytes.get("reduce_scatter", 0.0) + xfer
            continue

        if name == "all_to_all":
            pp = p.get("axis_size", _axes_prod(p.get("axis_name", ()),
                                               axis_sizes))
            b = _nbytes(eqn.invars[0].aval)
            xfer = b * (pp - 1) / max(pp, 1) * mult
            c.coll_bytes["all_to_all"] = \
                c.coll_bytes.get("all_to_all", 0.0) + xfer
            continue

        if name == "scan":
            count_costs(p["jaxpr"], axis_sizes, c,
                        mult * float(p.get("length", 1)))
            continue

        if name == "while":
            c.unknown_while += 1
            for sub in _sub_jaxprs(p):
                count_costs(sub, axis_sizes, c, mult)
            continue

        if name == "cond":
            # conservative: count the most expensive branch
            best, best_fl = None, -1.0
            for sub in _sub_jaxprs(p):
                probe = count_costs(sub, axis_sizes, Costs(), mult)
                if probe.flops > best_fl:
                    best, best_fl = probe, probe.flops
            if best is not None:
                _merge(c, best)
            continue

        # generic recursion (shard_map, pjit, remat2, custom_*_call, ...)
        for sub in _sub_jaxprs(p):
            count_costs(sub, axis_sizes, c, mult)

    return c


def _merge(dst: Costs, src: Costs) -> None:
    dst.flops += src.flops
    dst.dot_bytes += src.dot_bytes
    for k, v in src.coll_bytes.items():
        dst.coll_bytes[k] = dst.coll_bytes.get(k, 0.0) + v
    dst.ppermute_fwd_bytes += src.ppermute_fwd_bytes
    dst.ppermute_bwd_bytes += src.ppermute_bwd_bytes
    dst.ppermute_fwd_steps += src.ppermute_fwd_steps
    dst.ppermute_bwd_steps += src.ppermute_bwd_steps
    dst.unknown_while += src.unknown_while
