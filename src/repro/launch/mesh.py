"""Production meshes and the shard_map step builders.

Mesh shapes (DESIGN.md §5):

* single-pod: ``(16, 16)`` over ``("data", "model")`` — 256 chips (one
  TPU v5e pod slice).  ``data`` carries DP + FSDP, ``model`` carries
  TP/EP/SP.
* multi-pod: ``(2, 16, 16)`` over ``("pod", "data", "model")`` — 512
  chips; ``pod`` is an extra pure-DP axis (gradients cross pods once per
  step, hierarchically: AD's reduce over ``data`` first, then the ring
  over ``pod`` on already-reduced shards).

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh

from repro.core.modes import CommConfig, CommMode
from repro.core.progress import EndpointSpec
from repro.distributed.comm import Comm


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_comm(mesh: Mesh, config: Optional[CommConfig] = None, *,
              fsdp: bool = True,
              endpoint: Optional[EndpointSpec] = None) -> Comm:
    """Build the step Comm; ``endpoint`` picks the resource bundle the
    step's collectives ride (its width becomes the channel count)."""
    return Comm(config or CommConfig(), model_axis="model",
                data_axis=data_axes(mesh), fsdp=fsdp, endpoint=endpoint)


def shard(mesh: Mesh, tree_pspecs):
    """pspec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_pspecs,
        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(cfg, shape_kind: str, mesh: Mesh, *, batch: int
                 ) -> Dict[str, P]:
    """PartitionSpecs for the batch dict of one cell."""
    daxes = data_axes(mesh)
    if shape_kind == "decode":
        tok = P() if batch == 1 else P(daxes)
        out = {"tokens": tok}
    else:
        out = {"tokens": P("model", daxes), "labels": P("model", daxes)}
    if cfg.family == "vlm":
        out["image_embeds"] = P(None, daxes if batch > 1 else None, None)
    if cfg.is_encdec:
        out["frames"] = P("model", daxes if batch > 1 else None, None)
    if shape_kind == "decode":
        out.pop("labels", None)
    return out
