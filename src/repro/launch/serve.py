"""Serving launcher: continuous batching with the LCI scheduler on CPU.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 16 --max-new 12

``--transport`` routes requests over the host runtime's endpoints:
prompts ride a by-size-striped prefill endpoint, generated tokens a
separate decode endpoint (size-class isolation, DESIGN.md §8).
"""
import os

if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DEVICES"])

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.core.runtime import LocalCluster
from repro.models.registry import build_model
from repro.serving import PagedKVAllocator, ServeScheduler, ServeTransport
from repro.serving.engine import DecodeCache, init_cache, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--transport", action="store_true",
                    help="route requests over prefill/decode endpoints")
    ap.add_argument("--prefill-devices", type=int, default=2)
    ap.add_argument("--drain-workers", type=int, default=0,
                    help="drain the result CQ from N worker threads "
                         "(thread-safe LCQ-backed queue, DESIGN.md §10)")
    ap.add_argument("--attr", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="runtime-level attribute override for the "
                         "transport cluster (repeatable; e.g. "
                         "--attr rdv_threshold=4096 — DESIGN.md §12)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm",) or cfg.is_encdec:
        raise SystemExit("serve demo targets decoder-only archs")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    cache = init_cache(cfg, args.cache_len, args.max_batch)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    state = {"cache": cache}

    def decode_fn(tokens, positions):
        # the engine decodes the whole active batch at the scheduler's
        # current position front (the cache length is the batch max; the
        # per-request positions drive masking through valid_len)
        pad = args.max_batch - len(tokens)
        toks = jnp.asarray(np.pad(tokens, (0, pad)), jnp.int32)
        nxt, state["cache"] = serve(params, state["cache"], toks)
        return np.asarray(nxt)[:len(tokens)]

    alloc = PagedKVAllocator(n_pages=256, page_size=16)
    transport = None
    if args.attr and not args.transport:
        raise SystemExit("--attr tunes the transport cluster; it needs "
                         "--transport (without it there is no host "
                         "runtime to configure)")
    if args.transport:
        from repro.core.attrs import parse_attr_args
        cluster = LocalCluster(2, attrs=parse_attr_args(args.attr))
        transport = ServeTransport(cluster,
                                   n_prefill=args.prefill_devices)
        echo = cluster.attrs_echo()
        overridden = {k: v for k, v in echo["values"].items()
                      if echo["sources"].get(k) not in (None, "default",
                                                        "discovered")}
        if overridden:
            print(f"[serve] transport attrs (non-default): {overridden}")
    sched = ServeScheduler(decode_fn, max_batch=args.max_batch,
                           allocator=alloc, transport=transport)
    if args.drain_workers > 0 and transport is not None:
        raise SystemExit("--drain-workers drains the local result CQ; "
                         "with --transport results arrive via "
                         "transport.poll_results() instead — pick one")
    # unified comp API (routes via transport when present); worker-thread
    # draining needs the thread-safe LCQ backend
    cq = sched.alloc_cq(threadsafe=args.drain_workers > 0)
    drain = (sched.start_result_drain(cq, args.drain_workers)
             if args.drain_workers > 0 else None)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8)
        if transport is not None:
            sched.submit_remote(prompt, args.max_new)
        else:
            st = sched.submit(prompt, args.max_new, comp=cq,
                              allow_retry=False)
            assert not st.is_retry()
    steps = 0
    n_tok = 0
    while sched.completed < args.requests:
        sched.step()
        if transport is not None:
            transport.pump()
            for _rid, toks in transport.poll_results():
                n_tok += len(toks)
        steps += 1
        if steps > args.requests * args.max_new * 4:
            raise SystemExit("scheduler stalled")
    dt = time.time() - t0
    if transport is not None:
        transport.pump()
        for _rid, toks in transport.poll_results():
            n_tok += len(toks)
        per_dev = [d["posts"] for d in
                   transport.counters()["prefill"][0]["devices"]]
        print(f"[serve] prefill endpoint posts per device: {per_dev}")
    from repro.core.concurrency import drain as drain_cq
    if drain is not None:
        for st in drain.stop():
            n_tok += len(st.get_buffer())
        print(f"[serve] {args.drain_workers} drain workers collected "
              f"{sched.completed} results concurrently")
    for st in drain_cq(cq):
        n_tok += len(st.get_buffer())
    print(f"[serve] {args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, {steps} engine rounds, "
          f"{sched.retries} admission retries)")


if __name__ == "__main__":
    main()
