"""Flash attention Pallas TPU kernel — explicit VMEM tiling.

TPU adaptation of the flash recurrence (DESIGN/HW-adaptation): the KV loop
is a *grid dimension* with ``arbitrary`` semantics, so Mosaic keeps the
(m, l, acc) state resident in VMEM scratch across KV steps while the MXU
consumes (block_q × dh)·(dh × block_k) tiles; q/k/v blocks stream
HBM→VMEM via BlockSpecs.  Block shapes default to MXU-aligned
(128, 128)·dh multiples.

Layout: q (b, hq, sq, dh); k/v (b, hkv, skv, dh); GQA via per-q-head kv
index mapping (hq % hkv == 0).  Causal and sliding-window masks are
applied from global positions (``q_offset`` supports SP-local q).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _compiler_params(dimension_semantics, interpret: bool):
    if interpret:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except (AttributeError, TypeError):     # older pallas naming
        return pltpu.TPUCompilerParams(
            dimension_semantics=dimension_semantics)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, q_offset: int,
                 block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(2)              # q-block index ("parallel")
    ki = pl.program_id(3)              # kv-block index ("arbitrary": last)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    q_pos = q_offset + qi * block_q + lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q (b, hq, sq, dh); k/v (b, hkv, skv, dh) -> (b, hq, sq, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    while sq % block_q:
        block_q //= 2
    while skv % block_k:
        block_k //= 2
    n_q, n_k = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k)

    grid = (b, hq, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running exp-sum
            pltpu.VMEM((block_q, dh), jnp.float32),    # accumulator
        ],
        interpret=interpret,
        compiler_params=_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary"), interpret),
    )(q, k, v)
