"""jit'd public wrapper: dispatches kernel vs oracle by backend.

The model stack's seq-major layout (s, b, h, dh) is adapted here; the
kernel itself works in (b, h, s, dh), the natural TPU tiling (last two
dims map to VMEM lanes/sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_tpu
from .ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128):
    """Seq-major API: q (sq, b, hq, dh); k/v (skv, b, hkv, dh)."""
    qt = q.transpose(1, 2, 0, 3)
    kt = k.transpose(1, 2, 0, 3)
    vt = v.transpose(1, 2, 0, 3)
    out = flash_attention_tpu(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, block_q=block_q,
                              block_k=block_k, interpret=not _on_tpu())
    return out.transpose(2, 0, 1, 3)
