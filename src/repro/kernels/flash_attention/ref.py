"""Pure-jnp oracle for the flash attention kernel (O(s²) memory)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q (b, hq, sq, dh); k/v (b, hkv, skv, dh) -> (b, hq, sq, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, dh).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)
