"""jit'd public wrappers for the doorbell stage-copy (DESIGN.md §13)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.packet_pool import pool_get_copy_n
from .kernel import stage_copy_tpu
from .ref import _rows_to_bytes


@functools.partial(jax.jit, static_argnames=("wire_bf16",))
def stage_copy(payloads: jax.Array, *, wire_bf16: bool = False
               ) -> jax.Array:
    """(k, e) payloads -> (k, row_bytes) packed uint8 wire image, one
    dispatch: the Pallas tile copy applies the wire-dtype cast and the
    byte view is a free bitcast on the staged result."""
    staged = stage_copy_tpu(payloads, wire_bf16=wire_bf16,
                            interpret=jax.default_backend() != "tpu")
    return _rows_to_bytes(staged)


@functools.partial(jax.jit, static_argnames=("wire_bf16",))
def stage_copy_push(pool, buf, lane, payloads, steal_seed, *,
                    wire_bf16: bool = False):
    """The fused stage-copy-push: ONE dispatch stages the doorbell's
    payloads into wire bytes (bf16-compressing when asked), pops a burst
    of packet slots, and scatters the wire rows into the pool's backing
    buffers.  Returns ``(pool', buf', ids, got, status)`` with
    :func:`repro.core.packet_pool.pool_get_copy_n`'s contract — on a
    short grab only the allocated prefix is written."""
    staged = stage_copy_tpu(payloads, wire_bf16=wire_bf16,
                            interpret=jax.default_backend() != "tpu")
    rows = _rows_to_bytes(staged)
    return pool_get_copy_n(pool, buf, lane, rows, steal_seed)
