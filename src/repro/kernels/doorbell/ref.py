"""Pure-jnp oracle for the doorbell stage-copy (DESIGN.md §13)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _rows_to_bytes(rows: jax.Array) -> jax.Array:
    """(k, e) any-dtype -> (k, e * itemsize) uint8 wire rows."""
    b = jax.lax.bitcast_convert_type(rows, jnp.uint8)
    return b.reshape(rows.shape[0], -1)


def stage_copy_ref(payloads: jax.Array, *, wire_bf16: bool = False
                   ) -> jax.Array:
    """(k, e) payloads -> (k, row_bytes) packed uint8 wire image.

    Mirrors the host data plane's ``pack_payloads`` math: the staging
    copy IS the dtype normalization, and ``wire_bf16`` folds the f32 ->
    bf16 wire compression into that same copy (non-f32 bursts ship
    uncompressed, exactly like the host path).
    """
    if wire_bf16 and payloads.dtype == jnp.float32:
        payloads = payloads.astype(jnp.bfloat16)
    return _rows_to_bytes(payloads)
