"""Doorbell stage-copy Pallas kernel — row-blocked VMEM tiles.

Grid over row blocks of the (K, E) payload matrix; each step loads a
tile, applies the wire-dtype cast on the VPU (f32 -> bf16 when the
``wire_bf16`` attribute is on, identity otherwise), and writes the
staged tile.  The cast IS the copy: compression costs nothing beyond
the staging traffic the doorbell already pays (DESIGN.md §13).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage_copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


def stage_copy_tpu(x: jax.Array, *, wire_bf16: bool = False,
                   block_rows: int = 128, interpret: bool = True
                   ) -> jax.Array:
    """x (k, e) -> staged (k, e) in the wire dtype (bf16 when
    compressing an f32 burst, else x.dtype)."""
    k, e = x.shape
    out_dtype = (jnp.bfloat16 if wire_bf16 and x.dtype == jnp.float32
                 else x.dtype)
    block_rows = min(block_rows, k)
    while k % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    grid = (k // block_rows,)
    return pl.pallas_call(
        _stage_copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, e), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, e), out_dtype),
        interpret=interpret,
    )(x)
