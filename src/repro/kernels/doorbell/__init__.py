"""Doorbell stage-copy kernels (DESIGN.md §13).

The fused data plane's hot step — dtype-normalize a doorbell's K
payloads into one packed wire image and push it into the packet pool —
expressed as a Pallas kernel plus jitted wrappers so the in-graph
(functional-pool) path stages, compresses, and allocates in ONE
dispatch.  ``ref.py`` is the pure-jnp oracle, ``kernel.py`` the Pallas
TPU kernel, ``ops.py`` the public jitted entry points.
"""
from .ops import stage_copy, stage_copy_push
from .ref import stage_copy_ref

__all__ = ["stage_copy", "stage_copy_push", "stage_copy_ref"]
