"""RMSNorm Pallas TPU kernel — row-blocked VMEM tiles, fp32 statistics.

Grid over row blocks; each step loads a (block_rows, d) tile, computes the
per-row mean square in fp32 on the VPU, and writes the scaled tile.  d is
kept whole per tile (norm reductions are over the full feature dim; for
the assigned archs d ≤ 12288 → ≤ 3 MiB bf16 per tile, comfortably VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_tpu(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x (rows, d); w (d,) -> (rows, d)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
