"""jit'd public wrapper for the RMSNorm kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_tpu
from .ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256):
    """x (..., d); w (d,)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = rmsnorm_tpu(x2, w, eps=eps, block_rows=block_rows,
                      interpret=jax.default_backend() != "tpu")
    return out.reshape(shape)
