"""MoE grouped matmul Pallas TPU kernel.

Computes out[e] = act(x[e] @ w1[e]) @ w2[e] block-by-block: grid =
(experts, capacity blocks); per step the (block_c, d) token tile and the
expert's weights stream into VMEM and two MXU matmuls produce the tile.
This fuses the expert FFN so dispatched tokens make one HBM round trip
instead of three (the packet-pool slots are read once, written once).

``act``: 'swiglu' expects w1 = [gate|up] fused on the output dim (the
kernel splits the VMEM tile — a local, layout-safe split).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, w1_ref, w2_ref, o_ref, *, act: str):
    x = x_ref[0].astype(jnp.float32)             # (bc, d)
    w1 = w1_ref[0].astype(jnp.float32)           # (d, f or 2f)
    w2 = w2_ref[0].astype(jnp.float32)           # (f, d)
    h = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "swiglu":
        f = h.shape[-1] // 2
        h = jax.nn.silu(h[:, :f]) * h[:, f:]
    elif act == "geglu":
        f = h.shape[-1] // 2
        h = jax.nn.gelu(h[:, :f], approximate=True) * h[:, f:]
    elif act == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    o = jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)


def moe_gmm_tpu(x, w1, w2, *, act: str = "swiglu", block_c: int = 128,
                interpret: bool = True):
    """x (E, C, d); w1 (E, d, m·f); w2 (E, f, d) -> (E, C, d)."""
    e, cap, d = x.shape
    block_c = min(block_c, cap)
    while cap % block_c:
        block_c //= 2
    grid = (e, cap // block_c)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
            pl.BlockSpec((1, d, w1.shape[2]), lambda ei, ci: (ei, 0, 0)),
            pl.BlockSpec((1, w2.shape[1], d), lambda ei, ci: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cap, d), x.dtype),
        interpret=interpret,
    )(x, w1, w2)
