"""Pure-jnp oracle for the grouped-matmul kernel."""
import jax
import jax.numpy as jnp


def moe_gmm_ref(x, w1, w2, *, act: str = "swiglu"):
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w1.astype(jnp.float32))
    if act == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    elif act == "geglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.gelu(g, approximate=True) * u
    elif act == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    o = jnp.einsum("ecf,efd->ecd", h, w2.astype(jnp.float32))
    return o.astype(x.dtype)
