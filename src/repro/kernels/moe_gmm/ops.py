"""jit'd public wrapper for the MoE grouped matmul."""
from __future__ import annotations

import functools

import jax

from .kernel import moe_gmm_tpu
from .ref import moe_gmm_ref


@functools.partial(jax.jit, static_argnames=("act", "block_c"))
def moe_gmm(x, w1, w2, *, act: str = "swiglu", block_c: int = 128):
    return moe_gmm_tpu(x, w1, w2, act=act, block_c=block_c,
                       interpret=jax.default_backend() != "tpu")
