"""jit'd public wrapper for the SSD kernel (seq-major adapter)."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan_tpu
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, b, c, d_skip, *, chunk: int = 128):
    """Seq-major API matching repro.models.ssm.ssd_scan:
    x (s, bs, h, p); dt (s, bs, h); b/c (s, bs, g, n) -> (s, bs, h, p)."""
    xt = x.transpose(1, 2, 0, 3)
    dtt = dt.transpose(1, 2, 0)
    bt = b.transpose(1, 2, 0, 3)
    ct = c.transpose(1, 2, 0, 3)
    out = ssd_scan_tpu(xt, dtt, a_log, bt, ct, d_skip, chunk=chunk,
                       interpret=jax.default_backend() != "tpu")
    return out.transpose(2, 0, 1, 3)
