"""Mamba2 SSD Pallas TPU kernel — chunked scan with VMEM-resident state.

Grid = (batch, heads, chunks) with the chunk dimension ``arbitrary``
(sequential): the (N, P) recurrent state lives in VMEM scratch across
chunk steps, so the inter-chunk recurrence never round-trips HBM — the
TPU-native replacement for the GPU kernel's shared-memory state.  Each
step does the intra-chunk quadratic part as (L×L)·(L×P) MXU matmuls.

Layout: x (b, h, s, p); dt (b, h, s); B/C (b, g, s, n); per-head A_log/D.
Chunk length L is the MXU tile (default 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, o_ref,
                state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    a = -jnp.exp(alog_ref[0, 0].astype(jnp.float32))   # scalar
    b = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (L, N)
    d_skip = d_ref[0, 0].astype(jnp.float32)     # scalar

    la = dt * a                                  # (L,) log decay
    cum = jnp.cumsum(la)                         # (L,)
    xbar = x * dt[:, None]

    # intra-chunk: Y_diag[l] = Σ_{j<=l} (C_l·B_j) e^{cum_l-cum_j} xbar_j
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    li = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(li >= lj, scores * decay, 0.0)
    y = jax.lax.dot_general(m, xbar, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # incoming state contribution: C_l · H_in · e^{cum_l}
    h_in = state_scr[...]                        # (N, P)
    y = y + jax.lax.dot_general(
        c * jnp.exp(cum)[:, None], h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    o_ref[0, 0] = (y + d_skip * x).astype(o_ref.dtype)

    # state update: H_out = e^{cum_last} H_in + Σ_j e^{cum_last-cum_j} B_j⊗xbar_j
    dstate = jnp.exp(cum[-1] - cum)              # (L,)
    s_new = jax.lax.dot_general(
        b * dstate[:, None], xbar, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (N, P)
    state_scr[...] = jnp.exp(cum[-1]) * h_in + s_new


def ssd_scan_tpu(x, dt, a_log, b, c, d_skip, *, chunk: int = 128,
                 interpret: bool = True):
    """x (bs, h, s, p); dt (bs, h, s); a_log/d_skip (h,);
    b/c (bs, g, s, n).  Returns y (bs, h, s, p)."""
    bs, h, s, p = x.shape
    g, n = b.shape[1], b.shape[3]
    assert h % g == 0
    r = h // g
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (bs, h, n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (0, hi)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=r: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=r: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (0, hi)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log[None, :], b, c, d_skip[None, :])
