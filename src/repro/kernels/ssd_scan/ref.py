"""Pure-jnp oracle for the SSD kernel: the naive per-step recurrence."""
import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a_log, b, c, d_skip):
    """x (bs, h, s, p); dt (bs, h, s); b/c (bs, g, s, n) -> (bs, h, s, p)."""
    bs, h, s, p = x.shape
    g, n = b.shape[1], b.shape[3]
    r = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bf = jnp.repeat(b.astype(jnp.float32), r, axis=1)   # (bs, h, s, n)
    cf = jnp.repeat(c.astype(jnp.float32), r, axis=1)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hstate, t):
        at = jnp.exp(dtf[:, :, t] * a[None, :])         # (bs, h)
        upd = jnp.einsum("bhn,bhp->bhnp", bf[:, :, t],
                         xf[:, :, t] * dtf[:, :, t][..., None])
        hstate = at[..., None, None] * hstate + upd
        yt = jnp.einsum("bhn,bhnp->bhp", cf[:, :, t], hstate)
        return hstate, yt

    h0 = jnp.zeros((bs, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(s))
    ys = jnp.moveaxis(ys, 0, 2)                         # (bs, h, s, p)
    ys = ys + d_skip.astype(jnp.float32)[None, :, None, None] * xf
    return ys.astype(x.dtype)
