"""Transformer blocks: TP plans, attention ops, MLP/MoE wiring.

Tensor-parallel **plans** (DESIGN.md §4):

* Plan A (``shard_heads``) — q heads sharded over the model axis; entered
  with ``ag_matmul`` (full seq × local heads), exited with ``matmul_rs``.
  KV: sharded too when ``n_kv % tp == 0``; otherwise the KV projection is
  replicated (tiny: ``2·n_kv·dh`` wide) and each rank computes full KV.
* Plan B (``replicated heads``) — for archs whose head counts do not divide
  the model axis (gemma3: 4, hymba: 25, whisper: 6).  q is computed for the
  *local sequence rows only* (no gather), K/V are projected locally and
  ring-allgathered; attention has zero redundant FLOPs and the only
  collective is the small KV gather.  Weights are replicated over model
  (all these archs are <2B params) and FSDP-sharded over data at rest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .attention import flash_attention
from .common import ModelConfig, ParamFactory, shard_decisions
from .layers import apply_norm, apply_rope, mlp_activation, mlp_block, rms_norm


@dataclasses.dataclass(frozen=True)
class TPPlan:
    tp: int
    shard_heads: bool
    shard_kv: bool
    shard_ssm_heads: bool

    def q_local(self, cfg: ModelConfig) -> int:
        return cfg.n_heads // self.tp if self.shard_heads else cfg.n_heads

    def kv_local(self, cfg: ModelConfig) -> int:
        return cfg.n_kv_heads // self.tp if self.shard_kv else cfg.n_kv_heads


def tp_plan(cfg: ModelConfig, tp: int) -> TPPlan:
    dec = shard_decisions(cfg)
    if dec["attn"] and tp > 1:
        assert cfg.n_heads % tp == 0, \
            f"{cfg.name}: heads {cfg.n_heads} sharded at init but tp={tp}"
    if dec["ssm"] and tp > 1:
        assert cfg.ssm_heads % tp == 0
    return TPPlan(tp=tp, shard_heads=dec["attn"], shard_kv=dec["kv"],
                  shard_ssm_heads=dec["ssm"])


# ---------------------------------------------------------------------------
# parameter initialization for one attention + MLP block
# ---------------------------------------------------------------------------

def init_attention(pf: ParamFactory, cfg: ModelConfig, prefix: str = "",
                   stacked_layers: int = 0) -> Dict[str, jax.Array]:
    """Weights for one attention op (shapes are GLOBAL; sharding comes from
    the recorded ParamSpecs).  ``stacked_layers``>0 prepends an L dim."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    L = (stacked_layers,) if stacked_layers else ()
    st = bool(stacked_layers)
    dec = shard_decisions(cfg)
    a_shard, kv_shard = dec["attn"], dec["kv"]
    # K and V are stored as SEPARATE params: TP shards each on its own head
    # dim, and the use site concatenates the *local* shards — a fused
    # global [K|V] matrix sharded on the fused dim would hand each rank a
    # slice crossing the K/V boundary (see tests/test_distributed.py).
    p = {
        prefix + "wq": pf.dense(prefix + "wq", L + (d, nq * dh),
                                tp_axis=1 if a_shard else None,
                                fsdp_axis=0, stacked=st),
        prefix + "wk": pf.dense(prefix + "wk", L + (d, nkv * dh),
                                tp_axis=1 if kv_shard else None,
                                fsdp_axis=0, stacked=st),
        prefix + "wv": pf.dense(prefix + "wv", L + (d, nkv * dh),
                                tp_axis=1 if kv_shard else None,
                                fsdp_axis=0, stacked=st),
        prefix + "wo": pf.dense(prefix + "wo", L + (nq * dh, d),
                                tp_axis=0 if a_shard else None,
                                fsdp_axis=1, stacked=st),
    }
    if cfg.qk_norm:
        p[prefix + "q_norm"] = pf.ones(prefix + "q_norm", L + (dh,),
                                       stacked=st)
        p[prefix + "k_norm"] = pf.ones(prefix + "k_norm", L + (dh,),
                                       stacked=st)
    return p


def init_mlp(pf: ParamFactory, cfg: ModelConfig, prefix: str = "",
             stacked_layers: int = 0, d_ff: Optional[int] = None
             ) -> Dict[str, jax.Array]:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    L = (stacked_layers,) if stacked_layers else ()
    st = bool(stacked_layers)
    tp1 = 1 if cfg.tp_mlp else None
    tp0 = 0 if cfg.tp_mlp else None
    p = {
        prefix + "w_out": pf.dense(prefix + "w_out", L + (ff, d),
                                   tp_axis=tp0, fsdp_axis=1, stacked=st),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        # gate and up stored separately (same boundary argument as K/V)
        p[prefix + "w_gate"] = pf.dense(prefix + "w_gate", L + (d, ff),
                                        tp_axis=tp1, fsdp_axis=0,
                                        stacked=st)
        p[prefix + "w_up"] = pf.dense(prefix + "w_up", L + (d, ff),
                                      tp_axis=tp1, fsdp_axis=0, stacked=st)
    else:
        p[prefix + "w_in"] = pf.dense(prefix + "w_in", L + (d, ff),
                                      tp_axis=tp1, fsdp_axis=0, stacked=st)
    return p


# ---------------------------------------------------------------------------
# attention op (training/prefill; decode lives in repro.serving.engine)
# ---------------------------------------------------------------------------

def attention_op(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
                 comm, plan: TPPlan, *, window: int, q_offset,
                 memory: Optional[jax.Array] = None,
                 causal: bool = True, prefix: str = "") -> jax.Array:
    """x: (s_local, b, d) pre-normed; returns (s_local, b, d) un-residual.

    ``memory``: (t, b, d) full-length cross-attention source (replicated
    over the model axis) — when given, K/V come from it and masks are off.
    """
    dh = cfg.resolved_head_dim
    wq = comm.weight(p[prefix + "wq"], fsdp_axis=0)
    # concat of LOCAL shards: layout is [K_local | V_local] by construction
    wkv = jnp.concatenate(
        [comm.weight(p[prefix + "wk"], fsdp_axis=0),
         comm.weight(p[prefix + "wv"], fsdp_axis=0)], axis=1)
    wo = comm.weight(p[prefix + "wo"], fsdp_axis=1)
    kv_src = memory if memory is not None else x
    is_cross = memory is not None
    nq_l, nkv_l = plan.q_local(cfg), plan.kv_local(cfg)

    if plan.shard_heads:
        # Plan A: full-seq q for the local head shard.
        q = comm.ag_matmul(x, wq)                       # (s, b, nq_l*dh)
        if plan.shard_kv and not is_cross:
            kv = comm.ag_matmul(x, wkv)                 # (s, b, 2*nkv_l*dh)
            k, v = jnp.split(kv.reshape(*kv.shape[:-1], 2 * nkv_l, dh), 2,
                             axis=-2)
        else:
            # replicated KV projection: every rank computes ALL kv heads,
            # then slices the contiguous kv-head range its GLOBAL q heads
            # map to (GQA grouping is global, not local).
            kv_loc = jnp.tensordot(kv_src, wkv, axes=1)
            kv = kv_loc if is_cross else comm.ag_seq(kv_loc)
            kv = kv.reshape(*kv.shape[:-1], 2, nkv_l, dh)
            g_ratio = cfg.n_heads // cfg.n_kv_heads
            if nq_l >= g_ratio:
                assert nq_l % g_ratio == 0, (nq_l, g_ratio)
                cnt = nq_l // g_ratio
            else:
                assert g_ratio % nq_l == 0, (nq_l, g_ratio)
                cnt = 1
            rank = comm.model_index()
            start = (rank * nq_l) // g_ratio
            kv = jax.lax.dynamic_slice_in_dim(kv, start * jnp.int32(1),
                                              cnt, axis=-2)
            k, v = kv[..., 0, :, :], kv[..., 1, :, :]
        s_full = q.shape[0]
        q = q.reshape(s_full, *q.shape[1:-1], nq_l, dh)
        q_off_attn = 0                                  # q covers full seq
    else:
        # Plan B: local-seq q, all heads; KV gathered.
        q = jnp.tensordot(x, wq, axes=1)                # (s_l, b, nq*dh)
        kv_loc = jnp.tensordot(kv_src, wkv, axes=1)
        kv = kv_loc if is_cross else comm.ag_seq(kv_loc)
        q = q.reshape(*q.shape[:-1], nq_l, dh)
        k, v = jnp.split(kv.reshape(*kv.shape[:-1], 2 * nkv_l, dh), 2,
                         axis=-2)
        q_off_attn = q_offset

    if cfg.qk_norm:
        q = rms_norm(q, p[prefix + "q_norm"])
        k = rms_norm(k, p[prefix + "k_norm"])
    if not is_cross:                                    # RoPE (self-attn only)
        q_pos = q_off_attn + jnp.arange(q.shape[0], dtype=jnp.int32)
        k_pos = jnp.arange(k.shape[0], dtype=jnp.int32)
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)

    o = flash_attention(q, k, v, causal=causal and not is_cross,
                        window=0 if is_cross else window,
                        q_offset=q_off_attn)
    o = o.reshape(*o.shape[:-2], nq_l * dh)

    if plan.shard_heads:
        return comm.matmul_rs(o, wo)                    # (s_l, b, d)
    return jnp.tensordot(o, wo, axes=1)                 # already local rows


def layer_window(cfg: ModelConfig, layer_idx) -> jax.Array:
    """Effective attention window for layer ``layer_idx`` (traced ok).

    The global/local pattern (gemma3 5:1, hymba's explicit global layers)
    becomes *data*: a huge window == global attention, so the scan body has
    one code path and one collective schedule for every layer."""
    if cfg.sliding_window == 0:
        return jnp.int32(0)
    is_global = jnp.zeros((), bool)
    if cfg.swa_every_nth_global:
        is_global |= (layer_idx + 1) % cfg.swa_every_nth_global == 0
    for g in cfg.global_layers:
        is_global |= layer_idx == g
    return jnp.where(is_global, jnp.int32(1 << 30),
                     jnp.int32(cfg.sliding_window))


def swa_attention_op(x, p, cfg, comm, plan, *, layer_idx, q_offset,
                     prefix: str = "") -> jax.Array:
    """Attention with the per-layer global/local pattern."""
    w = layer_window(cfg, layer_idx) if cfg.sliding_window else 0
    return attention_op(x, p, cfg, comm, plan, window=w,
                        q_offset=q_offset, prefix=prefix)
