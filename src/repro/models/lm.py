"""Full language models: init + loss for every assigned architecture family.

Layer stacks are ``lax.scan``-rolled over stacked (L, ...) parameter
pytrees so that HLO size and compile time are O(1) in depth — a 104B-param
64-layer config compiles the same program as a 4-layer smoke config.  The
scan body is optionally ``jax.checkpoint``-ed (remat) for activation
memory.  All data movement inside blocks goes through ``Comm`` (LCI-X).

Batch convention (seq-major local view):
    tokens  (s_local, b)   int32
    labels  (s_local, b)   int32   (-100 = ignore)
    [frames (t_local, b, d)]        audio stub (whisper)
    [image_embeds (ti, b, d)]       vision stub (llama-3.2-vision)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.comm import Comm
from .blocks import (TPPlan, attention_op, init_attention, init_mlp,
                     layer_window, swa_attention_op, tp_plan)
from .common import ModelConfig, ParamFactory, ParamSpec
from .layers import (apply_norm, embed_tokens, lm_head_loss, mlp_block,
                     rms_norm, sinusoidal_positions)
from .moe import init_moe, moe_block
from .ssm import init_ssm, ssm_op


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_norm(pf: ParamFactory, cfg: ModelConfig, name: str, L: int):
    if cfg.norm == "layernorm_np":
        return {}                          # OLMo: non-parametric, no weight
    return {name: pf.ones(name, (L, cfg.d_model), stacked=True)}


def _init_layer_stack(pf: ParamFactory, cfg: ModelConfig, L: int,
                      *, causal_attn: bool = True) -> Dict[str, jax.Array]:
    """One homogeneous stack of L layers for the config's family."""
    p: Dict[str, jax.Array] = {}
    p.update(_init_norm(pf, cfg, "norm1", L))
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        p.update(init_attention(pf, cfg, stacked_layers=L))
    if cfg.family in ("ssm", "hybrid"):
        p.update(init_ssm(pf, cfg, stacked_layers=L))
    if cfg.family == "hybrid":
        p["mix_norm_a"] = pf.ones("mix_norm_a", (L, cfg.d_model),
                                  stacked=True)
        p["mix_norm_s"] = pf.ones("mix_norm_s", (L, cfg.d_model),
                                  stacked=True)
    if cfg.family == "moe":
        p.update(_init_norm(pf, cfg, "norm2", L))
        p.update(init_moe(pf, cfg, stacked_layers=L))
        if cfg.shared_expert_ff:
            p.update(init_mlp(pf, cfg, prefix="shared_", stacked_layers=L,
                              d_ff=cfg.shared_expert_ff))
    elif cfg.family != "ssm" and cfg.d_ff and not cfg.parallel_block:
        p.update(_init_norm(pf, cfg, "norm2", L))
        p.update(init_mlp(pf, cfg, stacked_layers=L))
    elif cfg.parallel_block and cfg.d_ff:
        p.update(init_mlp(pf, cfg, stacked_layers=L))   # shares norm1
    return p


def init_params(cfg: ModelConfig, key: jax.Array
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (params, specs) — parallel pytrees."""
    pf = ParamFactory(key, cfg.dtype, fsdp=cfg.fsdp_params)
    d = cfg.d_model
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    def grab(sub: Dict[str, jax.Array], dest_key: str):
        params[dest_key] = sub
        specs[dest_key] = {k: pf.specs[k] for k in sub}
        pf.specs.clear()

    # embedding: vocab (padded) TP-sharded, features FSDP-sharded
    params["emb"] = pf.dense("emb", (cfg.padded_vocab, d), tp_axis=0,
                             fsdp_axis=1, stacked=False, scale=1.0)
    specs["emb"] = pf.specs.pop("emb")
    if not cfg.tie_embeddings:
        params["lm_head"] = pf.dense("lm_head", (cfg.padded_vocab, d),
                                     tp_axis=0, fsdp_axis=1, stacked=False)
        specs["lm_head"] = pf.specs.pop("lm_head")
    params["final_norm"] = pf.ones("final_norm", (d,), stacked=False)
    specs["final_norm"] = pf.specs.pop("final_norm")

    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        grab(_init_layer_stack(pf, cfg, n_self), "layers")
        cp: Dict[str, jax.Array] = {}
        cp.update({"normx": pf.ones("normx", (n_cross, d), stacked=True)})
        cp.update(init_attention(pf, cfg, prefix="x_",
                                 stacked_layers=n_cross))
        cp["gate_attn"] = pf.zeros("gate_attn", (n_cross,), stacked=True,
                                   dtype=jnp.float32)
        cp.update({"normm": pf.ones("normm", (n_cross, d), stacked=True)})
        cp.update(init_mlp(pf, cfg, prefix="xm_", stacked_layers=n_cross))
        cp["gate_mlp"] = pf.zeros("gate_mlp", (n_cross,), stacked=True,
                                  dtype=jnp.float32)
        grab(cp, "cross_layers")
    elif cfg.is_encdec:
        grab(_init_layer_stack(pf, cfg, cfg.encoder_layers), "encoder")
        params["enc_final_norm"] = pf.ones("enc_final_norm", (d,),
                                           stacked=False)
        specs["enc_final_norm"] = pf.specs.pop("enc_final_norm")
        dp: Dict[str, jax.Array] = {}
        L = cfg.n_layers
        dp.update(_init_norm(pf, cfg, "norm1", L))
        dp.update(init_attention(pf, cfg, stacked_layers=L))
        dp.update({"normx": pf.ones("normx", (L, d), stacked=True)})
        dp.update(init_attention(pf, cfg, prefix="x_", stacked_layers=L))
        dp.update(_init_norm(pf, cfg, "norm2", L))
        dp.update(init_mlp(pf, cfg, stacked_layers=L))
        grab(dp, "layers")
    else:
        grab(_init_layer_stack(pf, cfg, cfg.n_layers), "layers")
    return params, specs


# ---------------------------------------------------------------------------
# blocks (scan bodies)
# ---------------------------------------------------------------------------

def _mlp_op(x, lp, cfg, comm, prefix: str = "") -> jax.Array:
    if cfg.mlp in ("swiglu", "geglu"):
        w_in = jnp.concatenate(
            [comm.weight(lp[prefix + "w_gate"], fsdp_axis=0),
             comm.weight(lp[prefix + "w_up"], fsdp_axis=0)], axis=1)
    else:
        w_in = comm.weight(lp[prefix + "w_in"], fsdp_axis=0)
    w_out = comm.weight(lp[prefix + "w_out"], fsdp_axis=1)
    if not cfg.tp_mlp:
        # SP-only MLP: weights replicated over model, tokens stay
        # seq-sharded — a pointwise op with ZERO collectives
        from .layers import mlp_activation
        h = mlp_activation(cfg.mlp, jnp.tensordot(x, w_in, axes=1))
        return jnp.tensordot(h, w_out, axes=1)
    return mlp_block(x, w_in, w_out, cfg.mlp, comm)


def _decoder_block(x, lp, idx, cfg: ModelConfig, comm: Comm, plan: TPPlan,
                   q_offset, memory=None) -> Tuple[jax.Array, Dict]:
    """One decoder layer of any family; returns (x', aux)."""
    aux: Dict[str, jax.Array] = {}
    h = apply_norm(cfg.norm, x, lp.get("norm1"))

    if cfg.family == "ssm":
        return x + ssm_op(h, lp, cfg, comm, plan), aux

    if cfg.family == "hybrid":
        a_out = swa_attention_op(h, lp, cfg, comm, plan, layer_idx=idx,
                                 q_offset=q_offset)
        s_out = ssm_op(h, lp, cfg, comm, plan)
        mix = 0.5 * (rms_norm(a_out, lp["mix_norm_a"])
                     + rms_norm(s_out, lp["mix_norm_s"]))
        x = x + mix
        h2 = apply_norm(cfg.norm, x, lp.get("norm2"))
        return x + _mlp_op(h2, lp, cfg, comm), aux

    attn = swa_attention_op(h, lp, cfg, comm, plan, layer_idx=idx,
                            q_offset=q_offset)
    if cfg.parallel_block:                       # Cohere: attn ∥ mlp
        return x + attn + _mlp_op(h, lp, cfg, comm), aux

    x = x + attn
    if memory is not None and "x_wq" in lp:      # enc-dec cross-attention
        hx = rms_norm(x, lp["normx"])
        x = x + attention_op(hx, lp, cfg, comm, plan, window=0,
                             q_offset=q_offset, memory=memory, prefix="x_")
    h2 = apply_norm(cfg.norm, x, lp.get("norm2"))
    if cfg.family == "moe":
        moe_out, aux = moe_block(h2, lp, cfg, comm)
        if cfg.shared_expert_ff:
            moe_out = moe_out + _mlp_op(h2, lp, cfg, comm, prefix="shared_")
        return x + moe_out, aux
    return x + _mlp_op(h2, lp, cfg, comm), aux


def _cross_block(x, lp, cfg, comm, plan, q_offset, memory):
    """Gated cross-attention layer (llama-3.2-vision style)."""
    hx = rms_norm(x, lp["normx"])
    attn = attention_op(hx, lp, cfg, comm, plan, window=0,
                        q_offset=q_offset, memory=memory, prefix="x_")
    x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * attn
    hm = rms_norm(x, lp["normm"])
    ff = _mlp_op(hm, lp, cfg, comm, prefix="xm_")
    return x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * ff


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

_AUX_KEYS = ("aux_lb", "aux_z", "dropped_frac")


def _scan_stack(x, stack, cfg, comm, plan, q_offset, *, body, remat: bool,
                length: int):
    idxs = jnp.arange(length, dtype=jnp.int32)

    def fn(carry, sl):
        xc, aux_acc = carry
        idx, lp = sl
        xc, aux = body(xc, lp, idx)
        aux_acc = {k: aux_acc[k] + aux.get(k, 0.0) for k in _AUX_KEYS}
        return (xc, aux_acc), ()

    if remat:
        fn = jax.checkpoint(fn, prevent_cse=False)
    aux0 = {k: jnp.zeros((), jnp.float32) for k in _AUX_KEYS}
    (x, aux), _ = jax.lax.scan(fn, (x, aux0), (idxs, stack))
    return x, aux


def forward(params: Dict[str, Any], batch: Dict[str, jax.Array],
            cfg: ModelConfig, comm: Comm, *, remat: bool = True
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (x_full (s, b, d) post-final-norm full-sequence, aux)."""
    plan = tp_plan(cfg, comm.tp)
    tokens = batch["tokens"]
    s_l, b = tokens.shape
    q_offset = comm.model_index() * s_l

    emb = comm.weight(params["emb"], fsdp_axis=1)
    x = embed_tokens(tokens, emb, comm,
                     scale_by_sqrt_dim=cfg.name.startswith("gemma"))

    memory = None
    if cfg.family == "vlm":
        memory = batch["image_embeds"]              # (ti, b, d) replicated
    if cfg.is_encdec:
        memory = _encode(params, batch, cfg, comm, plan, remat=remat)

    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1              # self layers per block
        stack = jax.tree_util.tree_map(
            lambda a: a.reshape((n_cross, per) + a.shape[1:]),
            params["layers"])
        cstack = params["cross_layers"]
        mem = memory

        def superblock(xc, lp_pair, idx):
            self_lp, cross_lp = lp_pair

            def inner(xc2, sl):
                j, lp = sl
                y, _ = _decoder_block(xc2, lp, idx * per + j, cfg, comm,
                                      plan, q_offset)
                return y, ()
            xc, _ = jax.lax.scan(
                inner, xc, (jnp.arange(per, dtype=jnp.int32), self_lp))
            xc = _cross_block(xc, cross_lp, cfg, comm, plan, q_offset, mem)
            return xc, {}

        x, aux = _scan_stack(
            x, (stack, cstack), cfg, comm, plan, q_offset,
            body=lambda xc, lp, idx: superblock(xc, lp, idx),
            remat=remat, length=n_cross)
    else:
        mem = memory

        def body(xc, lp, idx):
            return _decoder_block(xc, lp, idx, cfg, comm, plan, q_offset,
                                  memory=mem)

        x, aux = _scan_stack(x, params["layers"], cfg, comm, plan,
                             q_offset, body=body, remat=remat,
                             length=cfg.n_layers)

    x = apply_norm("rmsnorm" if cfg.norm == "rmsnorm" else "layernorm",
                   x, params["final_norm"])
    x = comm.ag_seq(x)                              # full seq for the head
    n_layers = max(cfg.n_layers, 1)
    # aux terms (router losses) are computed from *local* tokens, so they
    # vary across the model axis; grad-exact-mean them so the total loss is
    # replicated (required for exact distributed gradients — see
    # Comm.psum_model_ge).
    tp = comm.tp
    aux = {k: comm.psum_model_ge(v / n_layers) / tp for k, v in aux.items()}
    return x, aux


def _encode(params, batch, cfg, comm, plan, *, remat: bool) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings -> full memory."""
    frames = batch["frames"]                        # (t_local, b, d)
    t_l, b, d = frames.shape
    offset = comm.model_index() * t_l
    pos = sinusoidal_positions(t_l, d, offset=offset).astype(frames.dtype)
    x = frames + pos[:, None, :]

    def body(xc, lp, idx):
        h = apply_norm(cfg.norm, xc, lp.get("norm1"))
        attn = attention_op(h, lp, cfg, comm, plan, window=0, q_offset=0,
                            causal=False)
        xc = xc + attn
        h2 = apply_norm(cfg.norm, xc, lp.get("norm2"))
        return xc + _mlp_op(h2, lp, cfg, comm), {}

    x, _ = _scan_stack(x, params["encoder"], cfg, comm, plan, 0,
                       body=body, remat=remat, length=cfg.encoder_layers)
    x = apply_norm("rmsnorm" if cfg.norm == "rmsnorm" else "layernorm",
                   x, params["enc_final_norm"])
    return comm.ag_seq(x)                           # memory: (t, b, d)


def loss_and_metrics(params, batch, cfg: ModelConfig, comm: Comm, *,
                     remat: bool = True, loss_chunk: int = 1024
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean CE (+ router aux) over this data shard; caller pmean's."""
    x, aux = forward(params, batch, cfg, comm, remat=remat)
    labels = comm.ag_seq(batch["labels"])           # (s, b)
    head = params.get("lm_head", params["emb"])
    head = comm.weight(head, fsdp_axis=1)

    s = x.shape[0]
    ck = min(loss_chunk, s)
    while s % ck:
        ck -= 1
    nck = s // ck

    def chunk_loss(args):
        xb, lb = args
        return lm_head_loss(xb, head, lb, comm, real_vocab=cfg.vocab)

    sums, ns = jax.lax.map(
        chunk_loss, (x.reshape(nck, ck, *x.shape[1:]),
                     labels.reshape(nck, ck, *labels.shape[1:])))
    total, n = sums.sum(), ns.sum()
    ce = total / jnp.maximum(n, 1)
    loss = (ce + cfg.router_aux_coef * aux["aux_lb"]
            + cfg.router_z_coef * aux["aux_z"])
    metrics = {"loss": loss, "ce": ce, "ntok": n, **aux}
    return loss, metrics
