"""Shared layers: norms, RoPE, MLPs, vocab-parallel embedding & loss.

All functions take the seq-major local view ``(s_local, b, d)`` and a
:class:`repro.distributed.Comm`.  Norm math is fp32 regardless of payload
dtype.  The embedding table is vocab-sharded over the model axis (TP) and
feature-sharded over data (FSDP); logits are never materialized at full
vocab width — the cross-entropy is computed vocab-parallel (max/sum-exp
psums over the model axis), which is what makes 256k-vocab configs fit.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: Optional[jax.Array], eps: float = 1e-6
             ) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x: jax.Array, w: Optional[jax.Array],
               b: Optional[jax.Array] = None, eps: float = 1e-5
               ) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, w: Optional[jax.Array]) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, w)
    if kind == "layernorm":
        return layer_norm(x, w)
    if kind == "layernorm_np":          # OLMo: non-parametric LN
        return layer_norm(x, None)
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (s, b, h, dh); positions: (s,) global positions (SP-offset)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (dh/2,)
    angles = positions.astype(jnp.float32)[:, None] * freqs   # (s, dh/2)
    cos = jnp.cos(angles)[:, None, None, :]
    sin = jnp.sin(angles)[:, None, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(s: int, d: int, offset=0) -> jax.Array:
    """Whisper-style sinusoidal embeddings: (s, d)."""
    pos = jnp.arange(s, dtype=jnp.float32) + offset
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs (TP: w_in column-parallel, w_out row-parallel)
# ---------------------------------------------------------------------------

def mlp_activation(kind: str, h: jax.Array) -> jax.Array:
    """Apply the nonlinearity; swiglu expects fused gate|up on last dim."""
    if kind == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        return jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype) * up
    if kind == "geglu":                  # gemma: gated tanh-GELU
        gate, up = jnp.split(h, 2, axis=-1)
        return jax.nn.gelu(gate.astype(jnp.float32),
                           approximate=True).astype(h.dtype) * up
    if kind == "gelu":
        return jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    if kind == "relu2":                  # Nemotron/Minitron squared ReLU
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(f"unknown mlp {kind!r}")


def mlp_block(x: jax.Array, w_in: jax.Array, w_out: jax.Array, kind: str,
              comm) -> jax.Array:
    """x: (s_local, b, d) -> (s_local, b, d).  ag_matmul in, matmul_rs out
    (the Megatron-SP schedule on LCI ring collectives)."""
    h = comm.ag_matmul(x, w_in)          # (s, b, ff_local[*2 if swiglu])
    h = mlp_activation(kind, h)
    return comm.matmul_rs(h, w_out)      # (s_local, b, d)


# ---------------------------------------------------------------------------
# vocab-parallel embedding + loss
# ---------------------------------------------------------------------------

def embed_tokens(tokens: jax.Array, emb: jax.Array, comm, *,
                 scale_by_sqrt_dim: bool = False) -> jax.Array:
    """tokens: (s_local, b) int32; emb: (V_local, d) vocab shard.
    Returns the *seq-local* embeddings (s_local, b, d).

    Tokens are seq-sharded over the same model axis that shards the vocab,
    so the assembly is: all-gather the (tiny, int32) token ids, look up the
    locally-owned vocab rows for the FULL sequence, then **reduce-scatter
    over the sequence axis** — one collective whose bytes equal a single
    activation scatter, and whose LCI-mode lowering is the ring schedule.
    (A psum here would be wrong: each rank's partial covers different
    vocab rows but the *same* full sequence; rs sums partials and hands
    each rank back its own rows.)
    """
    v_local, d = emb.shape
    tokens_full = comm.ag_seq(tokens)                  # (s, b)
    rank = comm.model_index()
    local = tokens_full - rank * v_local
    valid = (local >= 0) & (local < v_local)
    rows = jnp.take(emb, jnp.clip(local, 0, v_local - 1), axis=0)
    rows = jnp.where(valid[..., None], rows, 0).astype(jnp.float32)
    out = comm.rs_seq(rows, axis=0)                    # (s_local, b, d)
    if scale_by_sqrt_dim:
        out = out * math.sqrt(d)
    return out.astype(emb.dtype)


def lm_head_loss(x: jax.Array, emb: jax.Array, labels: jax.Array, comm, *,
                 real_vocab: int, z_coef: float = 0.0,
                 ignore_label: int = -100):
    """Vocab-parallel cross-entropy.

    x: (s, b, d) FULL-sequence activations (callers ag_seq first);
    emb: (V_local, d) head shard (tied or untied); labels: (s, b) global ids.
    Returns (sum_loss, n_tokens) — callers combine across data shards.
    Full-vocab logits never exist: only (s, b, V_local) per rank.
    """
    v_local = emb.shape[0]
    rank = comm.model_index()
    logits = jnp.tensordot(x.astype(jnp.float32),
                           emb.astype(jnp.float32).T, axes=1)
    # mask padded vocab slots (rows beyond the real vocab)
    gid = rank * v_local + jnp.arange(v_local)
    logits = jnp.where(gid[None, None, :] < real_vocab, logits, -1e30)

    # the max is for numerical stability only — constant wrt gradients.
    # stop_gradient BEFORE pmax: pmax has no JVP rule, so it must only ever
    # see non-differentiated values.
    m = comm.pmax_model(jax.lax.stop_gradient(logits.max(axis=-1)))
    # grad-exact psums: the CE is replicated across the model axis, so the
    # correct transpose of these reductions is identity (see Comm.psum_model_ge)
    se = comm.psum_model_ge(jnp.exp(logits - m[..., None]).sum(axis=-1))
    lse = m + jnp.log(se)                                     # (s, b)

    local_label = labels - rank * v_local
    valid = (local_label >= 0) & (local_label < v_local)
    tl_local = jnp.take_along_axis(
        logits, jnp.clip(local_label, 0, v_local - 1)[..., None], axis=-1
    )[..., 0]
    target_logit = comm.psum_model_ge(jnp.where(valid, tl_local, 0.0))

    keep = labels != ignore_label
    per_tok = (lse - target_logit) * keep
    if z_coef:
        per_tok = per_tok + z_coef * (lse * keep) ** 2
    return per_tok.sum(), keep.sum()


def lm_head_logits(x: jax.Array, emb: jax.Array, comm, *,
                   real_vocab: int) -> jax.Array:
    """Decode-path logits: x (b, d) one position -> (b, V_local) local
    shard (the serving engine samples vocab-parallel: argmax via local
    top-1 + psum-argmax combine)."""
    v_local = emb.shape[0]
    rank = comm.model_index()
    logits = jnp.tensordot(x.astype(jnp.float32),
                           emb.astype(jnp.float32).T, axes=1)
    gid = rank * v_local + jnp.arange(v_local)
    return jnp.where(gid[None, :] < real_vocab, logits, -1e30)


def greedy_sample(logits_local: jax.Array, comm) -> jax.Array:
    """Vocab-parallel argmax: (b, V_local) -> (b,) global token ids."""
    v_local = logits_local.shape[-1]
    rank = comm.model_index()
    local_best = jnp.argmax(logits_local, axis=-1)            # (b,)
    local_val = jnp.take_along_axis(
        logits_local, local_best[:, None], axis=-1)[:, 0]
    best_val = comm.pmax_model(local_val)
    mine = local_val >= best_val                              # ties: lowest rank
    gid = rank * v_local + local_best
    cand = jnp.where(mine, gid, jnp.iinfo(jnp.int32).max)
    return -comm.pmax_model(-cand)                            # global min
