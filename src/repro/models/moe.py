"""Mixture-of-Experts — MoE dispatch as an LCI active-message system.

The mapping (DESIGN.md §4, the *fullest* use of the paper's machinery):

* a token choosing expert ``e`` posts an **active message** whose *tag* is
  the expert id and whose *target rank* is the EP shard owning ``e``;
* the **matching engine** is the token→(expert, slot) assignment — the
  hash-bucket insert becomes a vectorized rank-in-expert computation;
* **packet-pool capacity slots**: each expert exposes ``capacity`` fixed
  slots per source rank (pre-registered packets); a token that finds the
  pool exhausted gets ``retry`` — here: it is *dropped* into the overflow
  ledger (the **backlog queue** analogue) and rides the residual stream;
* the **all-to-all** is the progress engine flushing aggregated messages
  (chunked over channels in LCI modes for compute overlap);
* the **combine** is the completion: each token's synchronizer joins its
  top-k expert replies weighted by router probabilities.

Experts are sharded over the ``model`` axis (EP == TP axis, standard for
MoE at TP≤experts); expert weights are additionally FSDP-sharded over
``data`` at rest.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamFactory
from .layers import mlp_activation


def init_moe(pf: ParamFactory, cfg: ModelConfig, stacked_layers: int = 0
             ) -> Dict[str, jax.Array]:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    mult = 2 if cfg.mlp in ("swiglu", "geglu") else 1
    L = (stacked_layers,) if stacked_layers else ()
    st = bool(stacked_layers)
    p = {
        "router": pf.dense("router", L + (d, e), tp_axis=None, fsdp_axis=0,
                           stacked=st, scale=0.1),
        # expert weights: EP on the expert dim, FSDP on d_model
        "we_in": pf.dense("we_in", L + (e, d, mult * ff), tp_axis=0,
                          fsdp_axis=1, stacked=st),
        "we_out": pf.dense("we_out", L + (e, ff, d), tp_axis=0,
                           fsdp_axis=2, stacked=st),
    }
    return p


def router_topk(logits: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array, Dict]:
    """Top-k routing with aux losses.

    logits: (T, E) fp32.  Returns (weights (T,k), experts (T,k) int32,
    probs (T,E), aux: dict of scalar losses/metrics).
    """
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)        # renormalize top-k
    # Switch-style load-balance loss over all k assignments
    e = logits.shape[-1]
    assign = jax.nn.one_hot(experts, e, dtype=jnp.float32).sum(axis=1)
    f = assign.mean(axis=0) * e / cfg.top_k               # dispatch fraction
    p_mean = probs.mean(axis=0) * e
    aux_lb = (f * p_mean).mean()
    lse = jax.nn.logsumexp(logits, axis=-1)
    aux_z = (lse * lse).mean()
    aux = {"aux_lb": aux_lb, "aux_z": aux_z}
    return weights.astype(jnp.float32), experts, probs, aux


def moe_block(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
              comm) -> Tuple[jax.Array, Dict]:
    """x: (s_local, b, d) pre-normed.  Returns (out (s_local, b, d), aux).

    Capacity per (expert, source-rank) = ceil(T·k/E · cf) rounded up to 8,
    where T is the *local* token count — fixed-size packet slots, so the
    a2a payload is static-shaped (a hard requirement under jit and exactly
    the paper's fixed-size pre-registered packet design).
    """
    s_l, b, d = x.shape
    t = s_l * b
    e, k = cfg.n_experts, cfg.top_k
    tp = comm.tp
    assert e % tp == 0, f"experts {e} must divide over model axis {tp}"
    e_local = e // tp

    xf = x.reshape(t, d)
    router_w = comm.weight(p["router"], fsdp_axis=0)
    logits = jnp.tensordot(xf.astype(jnp.float32),
                           router_w.astype(jnp.float32), axes=1)
    weights, experts, probs, aux = router_topk(logits, cfg)

    cap = int(-(-t * k // e) * cfg.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)                        # pad to 8

    # -- matching engine: slot assignment (position of each msg in its
    #    expert's packet queue), vectorized hash-bucket insert ------------
    flat_e = experts.reshape(t * k)                       # message tags
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (T·k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot             # rank within expert
    pos = (pos * onehot).sum(axis=-1)                     # (T·k,)
    keep = pos < cap                                      # packet available?
    dropped = (~keep).sum()                               # backlog ledger
    aux["dropped_frac"] = dropped.astype(jnp.float32) / (t * k)

    # -- stage payloads into packet slots: (E, cap, d) ---------------------
    slot_e = jnp.where(keep, flat_e, 0)
    slot_p = jnp.where(keep, pos, 0)
    payload = jnp.repeat(xf, k, axis=0)                   # (T·k, d)
    payload = jnp.where(keep[:, None], payload, 0).astype(x.dtype)
    dispatch = jnp.zeros((e, cap, d), x.dtype)
    dispatch = dispatch.at[slot_e, slot_p].add(payload)

    # -- progress: flush aggregated messages (all-to-all over EP axis) -----
    recv = comm.a2a(dispatch, split_axis=0, concat_axis=1)  # (E_l, cap·tp, d)

    # -- expert compute (grouped matmul over local experts) ----------------
    we_in = comm.weight(p["we_in"], fsdp_axis=1)          # (E_l, d, m·ff)
    we_out = comm.weight(p["we_out"], fsdp_axis=2)        # (E_l, ff, d)
    h = jnp.einsum("ecd,edf->ecf", recv, we_in,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = mlp_activation(cfg.mlp, h)
    out = jnp.einsum("ecf,efd->ecd", h, we_out,
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # -- completion: return replies, combine with synchronizer weights -----
    back = comm.a2a(out, split_axis=1, concat_axis=0)     # (E, cap, d)
    gathered = back.reshape(e * cap, d)[slot_e * cap + slot_p]
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (gathered.reshape(t, k, d).astype(jnp.float32)
                * weights[..., None]).sum(axis=1)
    return combined.reshape(s_l, b, d).astype(x.dtype), aux
