"""Model configuration and parameter-spec plumbing.

One :class:`ModelConfig` covers all ten assigned architectures; family-
specific fields are zero/empty when unused.  :class:`ParamSpec` records,
per parameter, which logical axis is tensor-parallel (sharded over the
``model`` mesh axis) and which is FSDP (sharded over ``data``); both the
shard_map ``in_specs`` and the GSPMD ``NamedSharding`` derive from it, so
there is exactly one source of truth for the layout.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _round_up(x: int, to: int) -> int:
    return ((x + to - 1) // to) * to


def shard_decisions(cfg: "ModelConfig") -> dict:
    """The single source of truth for what is TP-sharded: used by the
    parameter initializers (specs) AND the runtime TP plan, so layouts and
    compute plans can never disagree."""
    t = cfg.tp_target
    attn = cfg.n_heads > 0 and cfg.n_heads % t == 0
    kv = attn and cfg.n_kv_heads % t == 0
    ssm = cfg.ssm_state > 0 and (cfg.ssm_heads % t == 0)
    return {"attn": attn, "kv": kv, "ssm": ssm}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # norms / MLP / block structure
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_np
    mlp: str = "swiglu"              # swiglu | gelu | relu2
    parallel_block: bool = False     # attention & FFN in parallel (Cohere)
    tie_embeddings: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # attention pattern
    sliding_window: int = 0          # 0 = full attention everywhere
    swa_every_nth_global: int = 0    # e.g. 6 => layers 5,11,... global (5:1)
    global_layers: Tuple[int, ...] = ()   # explicit global layers (hymba)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv_kernel: int = 4
    ssm_groups: int = 1

    # VLM / enc-dec frontends (stubs provide embeddings directly)
    cross_attn_every: int = 0        # every Nth layer cross-attends (vlm)
    n_image_tokens: int = 0
    encoder_layers: int = 0          # >0 => encoder-decoder (whisper)
    n_audio_frames: int = 0

    # numerics
    dtype: Any = jnp.bfloat16

    # the model-axis width the parameter layout targets (production mesh);
    # runtime meshes must divide the sharded dims identically
    tp_target: int = 16

    # FSDP: shard the non-TP weight dim over the data axis at rest.  The
    # right choice is size-dependent: ~free capacity for >8B models, pure
    # collective overhead for small ones (§Perf cell 2) — hence a knob.
    fsdp_params: bool = True

    # TP for the MLP: sharding d_ff over the model axis buys memory but
    # costs an activation gather+scatter per layer.  For small models the
    # model axis should be SP-only: replicated MLP weights compute locally
    # on sequence shards with ZERO collectives (§Perf cell 2).
    tp_mlp: bool = True

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (MXU lane width & TP-divisible);
        padded logit slots are masked to -inf in the loss."""
        return _round_up(self.vocab, 128)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def uses_subquadratic_attention(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4 shape skips)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    def layer_is_global(self, i: int) -> bool:
        """Does layer ``i`` use full (global) attention?"""
        if self.sliding_window == 0:
            return True
        if i in self.global_layers:
            return True
        if self.swa_every_nth_global:
            return (i + 1) % self.swa_every_nth_global == 0
        return False

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, dh = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        per_layer = 0
        if self.family != "ssm":
            per_layer += d * (nq * dh) + 2 * d * (nkv * dh) + (nq * dh) * d
        if self.family in ("ssm", "hybrid"):
            di = self.ssm_d_inner
            per_layer += d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                              + self.ssm_heads)
            per_layer += di * d + self.ssm_conv_kernel * di + 2 * self.ssm_heads
        if self.n_experts:
            ff_mult = 3 if self.mlp == "swiglu" else 2
            per_layer += self.n_experts * ff_mult * d * self.d_ff
            per_layer += d * self.n_experts                    # router
            if self.shared_expert_ff:
                per_layer += ff_mult * d * self.shared_expert_ff
        elif self.d_ff:
            ff_mult = 3 if self.mlp == "swiglu" else 2
            per_layer += ff_mult * d * self.d_ff
        per_layer += 2 * d                                     # norms
        n_cross = 0
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
        cross = n_cross * (2 * d * (nq * dh) + 2 * d * (nkv * dh))
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * per_layer                  # (approx)
        return (self.n_layers * per_layer + cross + emb + enc + d)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        ff_mult = 3 if self.mlp == "swiglu" else 2
        all_experts = self.n_layers * self.n_experts * ff_mult * \
            self.d_model * self.d_ff
        active = self.n_layers * self.top_k * ff_mult * \
            self.d_model * self.d_ff
        return full - all_experts + active


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Layout metadata for one parameter (per-layer shape, pre-stacking).

    ``tp_axis``   — dim sharded over the ``model`` mesh axis (None = replicated).
    ``fsdp_axis`` — dim sharded over ``data`` at rest (None = replicated);
                    gathered by ``Comm.weight`` right before use.
    ``stacked``   — True for per-layer params stored as (L, ...) under scan;
                    mesh dims shift right by one.
    """
    tp_axis: Optional[int] = None
    fsdp_axis: Optional[int] = None
    stacked: bool = True

    def pspec(self, *, model_axis="model", data_axis="data",
              stacked: Optional[bool] = None, ndim: Optional[int] = None):
        """PartitionSpec for shard_map in_specs / GSPMD NamedSharding."""
        from jax.sharding import PartitionSpec as P
        st = self.stacked if stacked is None else stacked
        off = 1 if st else 0
        set_axes = [a for a in (self.tp_axis, self.fsdp_axis)
                    if a is not None]
        if not set_axes:
            return P()                       # fully replicated, any rank
        n = ndim if ndim is not None else 1 + max(set_axes)
        dims: list = [None] * (n + off)
        if self.tp_axis is not None:
            dims[self.tp_axis + off] = model_axis
        if self.fsdp_axis is not None:
            dims[self.fsdp_axis + off] = data_axis
        return P(*dims)


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / math.sqrt(shape[0] if len(shape) > 1 else 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


class ParamFactory:
    """Init-time helper that records a ParamSpec for every created param."""

    def __init__(self, key: jax.Array, dtype, fsdp: bool = True):
        self._key = key
        self.dtype = dtype
        self.fsdp = fsdp
        self.specs: Dict[str, ParamSpec] = {}

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, name: str, shape: Tuple[int, ...], *,
              tp_axis: Optional[int], fsdp_axis: Optional[int],
              stacked: bool = True, scale: float = 1.0) -> jax.Array:
        if not self.fsdp:
            fsdp_axis = None
        self.specs[name] = ParamSpec(tp_axis, fsdp_axis, stacked)
        return truncated_normal_init(self.next_key(), shape, scale,
                                     self.dtype)

    def zeros(self, name: str, shape: Tuple[int, ...], *,
              tp_axis: Optional[int] = None,
              fsdp_axis: Optional[int] = None, stacked: bool = True,
              dtype=None) -> jax.Array:
        self.specs[name] = ParamSpec(tp_axis, fsdp_axis, stacked)
        return jnp.zeros(shape, dtype or self.dtype)

    def ones(self, name: str, shape: Tuple[int, ...], *,
             tp_axis: Optional[int] = None,
             fsdp_axis: Optional[int] = None, stacked: bool = True,
             dtype=None) -> jax.Array:
        self.specs[name] = ParamSpec(tp_axis, fsdp_axis, stacked)
        return jnp.ones(shape, dtype or self.dtype)
