"""Attention: chunked flash attention (jnp), GQA, SWA, and flash-decode.

Layout convention everywhere: activations are **seq-major local view**
``(s_local, batch, ...)`` — the natural layout for sequence parallelism
(the SP dim is dim 0, which is what the ring collectives shard).

Two tensor-parallel plans (picked by :func:`repro.models.blocks.tp_plan`):

* **Plan A (sharded heads)** — q/k/v for *all* sequence positions but only
  the local head shard; entered via ``Comm.ag_matmul`` (ring-overlapped).
* **Plan B (replicated heads)** — q for *local* sequence rows only, all
  heads; K/V projected locally and ring-allgathered over the model axis.
  Used when ``n_heads % tp != 0`` (gemma3's 4 heads, hymba's 25, whisper's
  6); zero redundant FLOPs, and the only collective is the small KV gather.

The quadratic part is computed block-by-block with an online softmax — the
flash-attention recurrence expressed as ``lax.scan`` so that (a) the HLO
stays O(1) in sequence length, and (b) peak memory is O(s·d + block²).
The Pallas kernel in :mod:`repro.kernels.flash_attention` implements the
same recurrence with explicit VMEM tiling for TPU; this module is also its
reference oracle (they are tested against each other).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _pick_block(s: int, preferred: int) -> int:
    """Largest divisor of ``s`` that is <= preferred (falls back to s)."""
    b = min(preferred, s)
    while s % b:
        b -= 1
    return max(b, 1)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=0,
                    q_offset=0, block_q: int = 256, block_k: int = 512,
                    ) -> jax.Array:
    """Chunked attention with online softmax.

    q: (sq, b, hq, dh); k/v: (skv, b, hkv, dh) with hq % hkv == 0 (GQA).
    ``q_offset`` — global position of q row 0 (SP: rank * s_local).
    ``window`` — sliding-window attention (key j visible to query i iff
    ``i - window < j <= i`` in global positions).  May be a *traced* scalar
    (layer-patterned SWA: the 5:1 local/global choice is data, keeping one
    collective path through the scan body); 0/None disables.  ``causal=
    False`` with no window is full bidirectional (encoder/cross-attention).
    Returns (sq, b, hq, dh) in q.dtype; softmax in fp32.
    """
    sq, b, hq, dh = q.shape
    skv, _, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    bq = _pick_block(sq, block_q)
    bk = _pick_block(skv, block_k)
    nq, nk = sq // bq, skv // bk

    # (nq, bq, b, hkv, g, dh) — blocked, GQA-grouped
    qb = q.reshape(nq, bq, b, hkv, g, dh).astype(jnp.float32) * scale
    kb = k.reshape(nk, bk, b, hkv, dh).astype(jnp.float32)
    vb = v.reshape(nk, bk, b, hkv, dh).astype(jnp.float32)
    q_offset = jnp.asarray(q_offset, jnp.int32)
    use_window = window is not None and not (
        isinstance(window, int) and window == 0)
    window = jnp.asarray(window if use_window else 0, jnp.int32)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * bq + jnp.arange(bq, dtype=jnp.int32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * bk + jnp.arange(bk, dtype=jnp.int32)
            # scores: (b, hkv, g, bq, bk)
            s = jnp.einsum("qbhgd,kbhd->bhgqk", q_blk, k_blk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if use_window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,kbhd->bhgqd", p, v_blk)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, dh), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        # (b, hkv, g, bq, dh) -> (bq, b, hkv, g, dh)
        return jnp.transpose(out, (3, 0, 1, 2, 4))

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq, dtype=jnp.int32), qb))
    out = outs.reshape(sq, b, hkv, g, dh).reshape(sq, b, hq, dh)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     *, valid_len=None, kv_offset=0, window=0,
                     q_pos=None, block_k: int = 1024) -> tuple:
    """One-token attention against a (possibly sharded) KV slice.

    q: (b, hq, dh); k_cache/v_cache: (skv_local, b, hkv, dh).
    Returns ``(num, m, l)`` — the *partial* flash-decode triple:
    num (b, hq, dh) unnormalized output, m (b, hq) running max, l (b, hq)
    exp-sum.  Shard-parallel callers combine partials across the KV-sharding
    axis with :func:`combine_decode_partials`; single-shard callers finish
    with ``num / l``.

    ``kv_offset`` — global position of cache row 0 (seq-sharded cache);
    ``valid_len`` — #globally valid cache rows (traced ok); ``q_pos`` — the
    query's global position (defaults to valid_len - 1 + nothing... callers
    pass it explicitly for windowed attention).
    """
    skv, b, hkv, dh = k_cache.shape
    hq = q.shape[1]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    qf = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    pos = kv_offset + jnp.arange(skv, dtype=jnp.int32)
    valid = jnp.ones((skv,), bool)
    if valid_len is not None:
        valid &= pos < valid_len
    use_window = window is not None and not (
        isinstance(window, int) and window == 0)
    if use_window and q_pos is not None:
        valid &= pos > q_pos - jnp.asarray(window, jnp.int32)

    bk = _pick_block(skv, block_k)
    nk = skv // bk
    kb = kf.reshape(nk, bk, b, hkv, dh)
    vb = vf.reshape(nk, bk, b, hkv, dh)
    maskb = valid.reshape(nk, bk)

    def step(carry, inputs):
        m, l, acc = carry
        k_blk, v_blk, msk = inputs
        s = jnp.einsum("bhgd,kbhd->bhgk", qf, k_blk)
        s = jnp.where(msk[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgk,kbhd->bhgd", p, v_blk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kb, vb, maskb))
    return (acc.reshape(b, hq, dh), m.reshape(b, hq), l.reshape(b, hq))


def combine_decode_partials(num, m, l, comm) -> jax.Array:
    """Combine flash-decode partials across the model axis (psum/pmax).

    The LCI reading: each KV shard is an independent *channel* whose partial
    completes asynchronously; the combine is the synchronizer (multi-signal
    completion object) joining them.
    """
    m_glob = comm.pmax_model(m)
    corr = jnp.exp(m - m_glob)
    l_glob = comm.psum_model(l * corr)
    num_glob = comm.psum_model(num * corr[..., None])
    return (num_glob / jnp.maximum(l_glob, 1e-37)[..., None])


def attention_reference(q, k, v, *, causal=True, window=0, q_offset=0):
    """O(s²)-memory oracle used by tests (materializes the score matrix)."""
    sq, b, hq, dh = q.shape
    skv, _, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(sq, b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("qbhgd,kbhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,kbhd->qbhgd", p, v.astype(jnp.float32))
    return out.reshape(sq, b, hq, dh).astype(q.dtype)
