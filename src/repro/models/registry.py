"""Model registry — build a Model facade from a ModelConfig."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax

from repro.distributed.comm import Comm, local_comm
from .common import ModelConfig
from . import lm


@dataclasses.dataclass(frozen=True)
class Model:
    """Facade: init + loss, comm-parameterized (local or shard_map)."""

    cfg: ModelConfig

    def init(self, key: jax.Array) -> Tuple[Dict, Dict]:
        return lm.init_params(self.cfg, key)

    def abstract_params(self, key: Optional[jax.Array] = None
                        ) -> Tuple[Dict, Dict]:
        """ShapeDtypeStruct params (no allocation) + specs — dry-run path."""
        key = key if key is not None else jax.random.PRNGKey(0)
        shapes = jax.eval_shape(lambda k: lm.init_params(self.cfg, k)[0],
                                key)
        _, specs = _specs_only(self.cfg)
        return shapes, specs

    def loss(self, params, batch, comm: Optional[Comm] = None, *,
             remat: bool = True):
        return lm.loss_and_metrics(params, batch, self.cfg,
                                   comm or local_comm(), remat=remat)

    def forward(self, params, batch, comm: Optional[Comm] = None, *,
                remat: bool = True):
        return lm.forward(params, batch, self.cfg, comm or local_comm(),
                          remat=remat)


def _specs_only(cfg: ModelConfig):
    """Specs without materializing params (init under eval_shape loses the
    side-band spec dict, so recompute it directly)."""
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    # init_params builds specs eagerly as a plain dict side channel; running
    # it under eval_shape executes the Python (cheap) without allocating.
    out = {}

    def capture(k):
        params, specs = lm.init_params(cfg, k)
        out["specs"] = specs
        return params

    jax.eval_shape(capture, key)
    return None, out["specs"]


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
