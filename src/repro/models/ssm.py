"""Mamba2 SSD (state-space duality, arXiv:2405.21060) — chunked scan.

The SSD layer computes, per head, the linear recurrence

    h_t = a_t · h_{t-1} + dt_t · (B_t ⊗ x_t),      y_t = C_t · h_t + D · x_t

with ``a_t = exp(dt_t · A)`` (A negative).  The chunked algorithm splits
the sequence into chunks of length L and evaluates:

  1. *intra-chunk* (quadratic within the chunk — the "duality" with
     attention: a masked decay-weighted score matrix),
  2. *chunk states* (each chunk's contribution to the running state),
  3. *inter-chunk* recurrence (a tiny scan over chunk summaries),
  4. *state→output* (incoming state projected through C).

TPU adaptation: the chunk length is the MXU-friendly tile (default 64);
all heavy ops are einsums.  The per-head recurrence is also exposed as
:func:`ssd_reference` (naive O(s·n·p) scan), which doubles as the Pallas
kernel's oracle.  Tensor layout is seq-major local view like everything
else: x (s, b, heads, headdim); B/C (s, b, groups, state).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamFactory, shard_decisions
from .layers import rms_norm


def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
             c: jax.Array, d_skip: jax.Array, *, chunk: int = 64,
             h0: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x: (s, bs, h, p); dt: (s, bs, h) (already softplus'd); a_log: (h,);
    b, c: (s, bs, g, n); d_skip: (h,); h0: (bs, h, n, p) initial state.
    Returns (y (s, bs, h, p), h_final (bs, h, n, p)).  fp32 internally.
    """
    s, bs, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    r = h // g                                  # heads per group
    L = min(chunk, s)
    while s % L:
        L -= 1
    nc = s // L

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))     # (h,) negative
    la = dtf * a                                # log a_t  (s, bs, h)
    xbar = xf * dtf[..., None]                  # dt-scaled input

    # chunked layout: (nc, L, bs, g, r, ...)
    def ck(t, extra=()):                        # (s, bs, ...) -> chunked
        return t.reshape((nc, L) + t.shape[1:])

    la_c = ck(la).reshape(nc, L, bs, g, r)
    cum = jnp.cumsum(la_c, axis=1)              # (nc, L, bs, g, r)
    xb_c = ck(xbar).reshape(nc, L, bs, g, r, p)
    b_c = ck(b.astype(jnp.float32))             # (nc, L, bs, g, n)
    c_c = ck(c.astype(jnp.float32))

    # 1. intra-chunk: Y_diag[l] = sum_{j<=l} (C_l·B_j) exp(cum_l-cum_j) xbar_j
    scores = jnp.einsum("clbgn,cjbgn->cljbg", c_c, b_c)      # (nc,L,L,bs,g)
    decay = jnp.exp(cum[:, :, None] - cum[:, None, :])       # (nc,L,L,bs,g,r)
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, :, :, None, None, None], decay, 0.0)
    y_diag = jnp.einsum("cljbg,cljbgr,cjbgrp->clbgrp", scores, decay, xb_c)

    # 2. chunk states: S_c = sum_j exp(cum_last - cum_j) B_j ⊗ xbar_j
    dstate = jnp.exp(cum[:, -1:] - cum)                      # (nc,L,bs,g,r)
    states = jnp.einsum("cjbgn,cjbgr,cjbgrp->cbgrnp", b_c, dstate, xb_c)

    # 3. inter-chunk recurrence over chunk summaries
    a_tot = jnp.exp(cum[:, -1])                              # (nc,bs,g,r)
    h_init = (jnp.zeros((bs, g, r, n, p), jnp.float32) if h0 is None
              else h0.astype(jnp.float32).reshape(bs, g, r, n, p))

    def step(hstate, inp):
        a_c, s_c = inp
        h_in = hstate
        h_out = a_c[..., None, None] * hstate + s_c
        return h_out, h_in

    h_final, h_in = jax.lax.scan(step, h_init, (a_tot, states))

    # 4. incoming state -> output: Y_off[l] = C_l · H_in · exp(cum_l)
    y_off = jnp.einsum("clbgn,cbgrnp,clbgr->clbgrp", c_c, h_in,
                       jnp.exp(cum))

    y = (y_diag + y_off).reshape(nc, L, bs, h, p).reshape(s, bs, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), h_final.reshape(bs, h, n, p)


def ssd_reference(x, dt, a_log, b, c, d_skip, h0=None):
    """Naive per-step recurrence oracle (same signature/returns)."""
    s, bs, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    r = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bf = jnp.repeat(b.astype(jnp.float32), r, axis=2)        # (s,bs,h,n)
    cf = jnp.repeat(c.astype(jnp.float32), r, axis=2)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    h_state = (jnp.zeros((bs, h, n, p), jnp.float32) if h0 is None
               else h0.astype(jnp.float32))

    def step(hs, inp):
        xt, dtt, bt, ct = inp                                # (bs,h,...)
        at = jnp.exp(dtt * a)                                # (bs,h)
        upd = jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        hs = at[..., None, None] * hs + upd
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hs)
        return hs, yt

    h_final, ys = jax.lax.scan(step, h_state, (xf, dtf, bf, cf))
    ys = ys + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return ys.astype(x.dtype), h_final


def ssd_decode_step(h_state, x_tok, dt_tok, a_log, b_tok, c_tok, d_skip):
    """One-token SSD update for serving.

    h_state (bs,h,n,p); x_tok (bs,h,p); dt_tok (bs,h); b/c_tok (bs,g,n).
    Returns (h_state', y (bs,h,p))."""
    bs, h, n, p = h_state.shape
    g = b_tok.shape[1]
    r = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bf = jnp.repeat(b_tok.astype(jnp.float32), r, axis=1)
    cf = jnp.repeat(c_tok.astype(jnp.float32), r, axis=1)
    dtf = dt_tok.astype(jnp.float32)
    xf = x_tok.astype(jnp.float32)
    at = jnp.exp(dtf * a)
    upd = jnp.einsum("bhn,bhp->bhnp", bf, xf * dtf[..., None])
    h_new = at[..., None, None] * h_state.astype(jnp.float32) + upd
    y = jnp.einsum("bhn,bhnp->bhp", cf, h_new)
    y = y + d_skip.astype(jnp.float32)[None, :, None] * xf
    return h_new, y.astype(x_tok.dtype)


# ---------------------------------------------------------------------------
# the full Mamba2 mixer (in-proj, conv, SSD, gated norm, out-proj)
# ---------------------------------------------------------------------------

def init_ssm(pf: ParamFactory, cfg: ModelConfig, stacked_layers: int = 0,
             prefix: str = "ssm_") -> Dict[str, jax.Array]:
    d, di = cfg.d_model, cfg.ssm_d_inner
    h, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    K = cfg.ssm_conv_kernel
    L = (stacked_layers,) if stacked_layers else ()
    st = bool(stacked_layers)
    shard = shard_decisions(cfg)["ssm"]
    tp1 = 1 if shard else None
    tp0 = 0 if shard else None

    def nm(s):
        return prefix + s

    # z and x stored separately: a fused [Z|X] matrix sharded on the fused
    # dim would hand each rank a slice crossing the Z/X boundary.
    p = {
        nm("w_z"): pf.dense(nm("w_z"), L + (d, di), tp_axis=tp1,
                            fsdp_axis=0, stacked=st),
        nm("w_x"): pf.dense(nm("w_x"), L + (d, di), tp_axis=tp1,
                            fsdp_axis=0, stacked=st),
        nm("w_dt"): pf.dense(nm("w_dt"), L + (d, h), tp_axis=tp1,
                             fsdp_axis=0, stacked=st),
        nm("w_bc"): pf.dense(nm("w_bc"), L + (d, 2 * g * n), tp_axis=None,
                             fsdp_axis=0, stacked=st),
        nm("conv_w"): pf.dense(nm("conv_w"), L + (K, di), tp_axis=tp1,
                               fsdp_axis=None, stacked=st, scale=0.5),
        nm("a_log"): pf.zeros(nm("a_log"), L + (h,), tp_axis=tp0,
                              stacked=st, dtype=jnp.float32),
        nm("d_skip"): pf.ones(nm("d_skip"), L + (h,), tp_axis=tp0,
                              stacked=st, dtype=jnp.float32),
        nm("dt_bias"): pf.zeros(nm("dt_bias"), L + (h,), tp_axis=tp0,
                                stacked=st, dtype=jnp.float32),
        nm("norm_w"): pf.ones(nm("norm_w"), L + (di,), tp_axis=tp0,
                              stacked=st),
        nm("w_out"): pf.dense(nm("w_out"), L + (di, d), tp_axis=tp0,
                              fsdp_axis=1, stacked=st),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over dim 0.  x (s, bs, ch), w (K, ch)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for k in range(1, K):
        shifted = jnp.pad(x[:-k], ((k, 0), (0, 0), (0, 0)))
        out = out + shifted * w[K - 1 - k]
    return out


def ssm_op(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
           comm, plan, *, prefix: str = "ssm_") -> jax.Array:
    """x: (s_local, bs, d) pre-normed -> (s_local, bs, d).

    Sharded-heads path: the fused [zx|dt] projection enters via ag_matmul
    (ring overlap), B/C are projected locally and seq-gathered (tiny), the
    SSD scan runs on local heads over the full sequence, and the output
    projection exits via matmul_rs.  Replicated path (hymba's 50 heads):
    everything is gathered, the scan is computed once per rank redundantly,
    and only the local rows are projected out (DESIGN.md notes the padding
    optimization as a hillclimb candidate).
    """
    def nm(s):
        return prefix + s

    s_l, bs, d = x.shape
    di, h = cfg.ssm_d_inner, cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    tp = comm.tp
    shard = plan.shard_ssm_heads
    h_l = h // tp if shard else h
    di_l = di // tp if shard else di

    # fused [Z_l | X_l | dt_l] from LOCAL shards (one gather, one matmul)
    fused = jnp.concatenate(
        [comm.weight(p[nm("w_z")], fsdp_axis=0),
         comm.weight(p[nm("w_x")], fsdp_axis=0),
         comm.weight(p[nm("w_dt")], fsdp_axis=0)], axis=1)
    w_bc = comm.weight(p[nm("w_bc")], fsdp_axis=0)
    w_out = comm.weight(p[nm("w_out")], fsdp_axis=1)

    if shard:
        zxdt = comm.ag_matmul(x, fused)                  # (s, bs, ...)
        bc = comm.ag_seq(jnp.tensordot(x, w_bc, axes=1))
    else:
        zxdt = comm.ag_seq(jnp.tensordot(x, fused, axes=1))
        bc = comm.ag_seq(jnp.tensordot(x, w_bc, axes=1))

    z, xs, dt_raw = jnp.split(zxdt, [di_l, 2 * di_l], axis=-1)
    b_proj, c_proj = jnp.split(bc, 2, axis=-1)
    s_full = zxdt.shape[0]

    xs = _causal_conv(xs, p[nm("conv_w")])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p[nm("dt_bias")].astype(jnp.float32))
    y, _ = ssd_scan(
        xs.reshape(s_full, bs, h_l, cfg.ssm_headdim), dt,
        p[nm("a_log")], b_proj.reshape(s_full, bs, g, n),
        c_proj.reshape(s_full, bs, g, n), p[nm("d_skip")],
        chunk=cfg.ssm_chunk)
    y = y.reshape(s_full, bs, di_l)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    # gated RMSNorm over the FULL d_inner: with channels TP-sharded the
    # sum-of-squares must be psum'd over the model axis, otherwise the
    # norm statistics silently depend on the shard width (shard-variant
    # semantics — caught by tests/test_distributed.py).
    yf = y.astype(jnp.float32)
    ssq = (yf * yf).sum(axis=-1, keepdims=True)
    denom = di_l
    if shard:
        # NOT grad-exact psum: this reduction feeds per-rank-varying values
        # (the normalized activations), not a replicated consumer; psum's
        # psum-transpose is the correct adjoint here (each rank's ssq
        # cotangent is the sum of all ranks' sensitivities to the shared
        # statistic).
        ssq = comm.psum_model(ssq)
        denom = di
    yf = yf * jax.lax.rsqrt(ssq / denom + 1e-6)
    y = (yf * p[nm("norm_w")].astype(jnp.float32)).astype(y.dtype)

    if shard:
        return comm.matmul_rs(y, w_out)                  # (s_l, bs, d)
    # replicated: slice local rows, project locally
    start = comm.model_index() * s_l
    y_local = jax.lax.dynamic_slice(
        y, (start, jnp.int32(0), jnp.int32(0)), (s_l, bs, di_l))
    return jnp.tensordot(y_local, w_out, axes=1)
