"""Model zoo: dense/GQA/SWA transformers, MoE, Mamba2 SSD, Hymba hybrid,
VLM cross-attention, Whisper encoder-decoder — all comm-parameterized."""
from .common import ModelConfig, ParamSpec
# registry imported lazily (populated as model families land)

__all__ = ["ModelConfig", "ParamSpec"]
