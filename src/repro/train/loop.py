"""Training loop: steps, async checkpoints, straggler stats, metrics log.

The loop owns the *operational* behaviour (DESIGN.md §7): resume from the
last committed checkpoint with exact data replay (step-indexed pipeline),
async checkpointing off the critical path, per-step timing with z-score
straggler flagging, and a metrics CSV for offline analysis.
"""
from __future__ import annotations

import csv
import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.distributed.straggler import StepTimeMonitor


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    log_every: int = 10
    metrics_csv: Optional[str] = None
    resume: bool = True


def train_loop(state, step_fn: Callable, pipeline, loop_cfg: LoopConfig,
               *, batch_transform: Optional[Callable] = None,
               on_step: Optional[Callable] = None):
    """Run the loop; returns (final_state, history list of metric dicts)."""
    start_step = 0
    store = None
    pending_save = None
    if loop_cfg.ckpt_dir:
        store = CheckpointStore(loop_cfg.ckpt_dir)
        if loop_cfg.resume and store.latest() is not None:
            abstract = jax.tree_util.tree_map(np.asarray, state)
            state, manifest = store.restore(abstract)
            start_step = manifest["meta"].get("next_step",
                                              manifest["step"] + 1)
            print(f"[loop] resumed from step {manifest['step']}, "
                  f"continuing at {start_step}")

    monitor = StepTimeMonitor()
    history = []
    writer = None
    csv_file = None
    if loop_cfg.metrics_csv:
        os.makedirs(os.path.dirname(loop_cfg.metrics_csv) or ".",
                    exist_ok=True)
        csv_file = open(loop_cfg.metrics_csv, "a", newline="")
        writer = csv.writer(csv_file)

    for step in range(start_step, loop_cfg.total_steps):
        batch = pipeline.get_batch(step)
        if batch_transform:
            batch = batch_transform(batch, step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(jax.tree_util.tree_leaves(metrics)[0])
        dt = time.perf_counter() - t0

        flagged = monitor.record(step, dt)
        if flagged is not None:
            print(f"[straggler] step {step}: {dt * 1e3:.1f} ms "
                  f"(z={flagged.zscore:.1f}, mean={flagged.mean * 1e3:.1f})")

        row = {"step": step, "dt": dt,
               **{k: float(np.asarray(v)) for k, v in metrics.items()}}
        history.append(row)
        if writer:
            if step == start_step:
                writer.writerow(list(row))
            writer.writerow(list(row.values()))
        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            print(f"[step {step}] loss={row.get('loss', float('nan')):.4f} "
                  f"dt={dt * 1e3:.1f}ms")
        if on_step:
            on_step(step, state, row)

        if store and loop_cfg.ckpt_every and \
                (step + 1) % loop_cfg.ckpt_every == 0:
            if pending_save is not None and not pending_save.ready:
                # previous async save still in flight: let it finish first
                while not pending_save.ready:
                    time.sleep(0.01)
            pending_save = store.save(step, state,
                                      meta={"next_step": step + 1})

    if store:
        if pending_save is not None:
            while not pending_save.ready:
                time.sleep(0.01)
        store.save(loop_cfg.total_steps - 1, state,
                   meta={"next_step": loop_cfg.total_steps}, blocking=True)
        store.gc()
    if csv_file:
        csv_file.close()
    print(f"[loop] done; straggler summary: {monitor.summary()}")
    return state, history
