"""The training step — fwd, bwd, grad sync, clip, AdamW — comm-local.

One function serves every deployment: local (CPU smoke), shard_map manual
SPMD (production; the dry-run lowers exactly this), and any CommMode
(BSP baseline vs LCI overlap schedules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.comm import Comm, local_comm
from repro.models.registry import Model
from repro.optim import (AdamWConfig, OptState, adamw_init, adamw_update,
                         clip_by_global_norm, grad_sync)


@dataclasses.dataclass
class TrainState:
    params: Dict[str, Any]
    opt: OptState


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(*c))


def train_state_init(model: Model, key: jax.Array, opt_cfg: AdamWConfig
                     ) -> Tuple[TrainState, Dict[str, Any]]:
    params, specs = model.init(key)
    return TrainState(params, adamw_init(params, opt_cfg)), specs


def make_train_step(model: Model, specs: Dict[str, Any],
                    opt_cfg: AdamWConfig,
                    comm: Optional[Comm] = None, *, remat: bool = True
                    ) -> Callable:
    comm = comm or local_comm()

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        def loss_fn(params):
            return model.loss(params, batch, comm, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads = grad_sync(grads, specs, comm)
        grads, gnorm = clip_by_global_norm(grads, specs, comm,
                                           opt_cfg.max_grad_norm)
        params, opt = adamw_update(grads, state.opt, state.params, opt_cfg)
        # metrics must leave the step fully replicated (shard_map out_specs
        # P()): mean every scalar over all mesh axes
        metrics = comm.pmean_all(
            {k: v.astype(jnp.float32) for k, v in metrics.items()})
        metrics["grad_norm"] = gnorm
        return TrainState(params, opt), metrics

    return train_step
