"""Sharded async checkpointing with atomic commit and elastic restore.

Layout on disk::

    <dir>/step_00001234/
        manifest.json        # tree structure, shapes, dtypes, hashes, meta
        <leaf-path>.npy      # one file per pytree leaf (host shard)
    <dir>/LATEST             # atomically-updated pointer

Fault-tolerance properties (DESIGN.md §7):

* **atomic commit** — leaves are written into ``step_*.tmp`` and the
  directory is ``rename``d only after every file (and the manifest with
  content hashes) is fsync'd; a crash mid-save never corrupts LATEST.
* **async** — ``save_async`` snapshots device arrays to host, then writes
  on a background thread; the returned LCI :class:`Synchronizer` is
  signaled on commit (the paper's completion-object protocol applied to
  I/O); ``sync.wait()`` blocks on it, ``sync.test()`` polls.  Training
  continues during the write.
* **the commit pipeline is a completion graph** — prepare → one write
  node per leaf → manifest → atomic rename → signal.  The partial order
  *is* the crash-safety argument (nothing renames before every leaf and
  the manifest are fsync'd), and it is asserted after every commit.
* **elastic restore** — the manifest stores *global* shapes; restore
  re-shards onto whatever mesh the new job runs (``restore_resharded``),
  so a checkpoint from a 256-chip run restores onto 512 chips and vice
  versa.
* **integrity** — every leaf file carries a SHA-256 in the manifest;
  restore verifies before handing arrays back.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.completion import Synchronizer
from repro.core.graph import CompletionGraph
from repro.core.status import FatalError, done

_EXECUTOR = cf.ThreadPoolExecutor(max_workers=2,
                                  thread_name_prefix="ckpt-writer")


def _leaf_files(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[name] = np.asarray(leaf)
    return out


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _write_leaf(tmp: str, name: str, arr: np.ndarray) -> tuple:
    path = os.path.join(tmp, name + ".npy")
    np.save(path, arr)
    with open(path, "rb") as f:
        os.fsync(f.fileno())
    return name, {"shape": list(arr.shape), "dtype": str(arr.dtype),
                  "sha256": _sha(arr)}


def build_commit_graph(ckpt_dir: str, step: int, host_tree: Any,
                       meta: Optional[Dict], sync: Synchronizer
                       ) -> CompletionGraph:
    """The commit pipeline as an LCI completion graph.

    prepare → write(leaf)* → manifest → rename-commit → signal(sync).
    The graph's partial order is the crash-safety invariant: the atomic
    rename fires only after every leaf write *and* the fsync'd manifest
    completed, and ``sync`` is signaled only after LATEST moved.
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def prepare():
        os.makedirs(ckpt_dir, exist_ok=True)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        return tmp

    def write_manifest(*leaf_infos):
        # the graph's queryable attrs (unified get_attr surface) ride the
        # manifest: a restore can see how the commit pipeline was shaped
        manifest = {"step": step, "meta": meta or {},
                    "commit_graph": {"n_nodes": g.get_attr("n_nodes"),
                                     "n_comm_nodes":
                                         g.get_attr("n_comm_nodes")},
                    "leaves": {name: info for name, info in leaf_infos}}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        return mpath

    def commit(_manifest_path):
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                   # atomic commit
        _update_latest(ckpt_dir, step)
        return final

    g = CompletionGraph(f"ckpt-commit-{step}")
    prep = g.add_node(prepare, name="prepare")
    writes = [g.add_node(lambda _tmp, n=name, a=arr: _write_leaf(_tmp, n, a),
                         deps=[prep], name=f"write:{name}")
              for name, arr in _leaf_files(host_tree).items()]
    man = g.add_node(write_manifest, deps=writes, name="manifest")
    com = g.add_node(commit, deps=[man], name="commit")
    g.add_node(lambda path: sync.signal(done(path)), deps=[com],
               name="signal")
    return g


def save_sync(ckpt_dir: str, step: int, tree: Any,
              meta: Optional[Dict] = None) -> str:
    """Blocking save with atomic rename commit. Returns final path."""
    host_tree = jax.tree_util.tree_map(np.asarray, tree)
    sync = Synchronizer(expected=1)
    g = build_commit_graph(ckpt_dir, step, host_tree, meta, sync)
    g.execute()                                 # host-only graph: synchronous
    g.assert_partial_order()
    (status,) = sync.wait()
    return status.get_buffer()


def _update_latest(ckpt_dir: str, step: int) -> None:
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def save_async(ckpt_dir: str, step: int, tree: Any,
               meta: Optional[Dict] = None) -> Synchronizer:
    """Snapshot to host now; write + commit on a background thread.

    Returns an LCI Synchronizer signaled (once) when the commit lands;
    ``sync.wait()`` blocks until then (no progress driver needed — the
    writer thread delivers the signal), ``sync.test()`` polls.
    """
    host_tree = jax.tree_util.tree_map(np.asarray, tree)   # device->host now
    sync = Synchronizer(expected=1)
    g = build_commit_graph(ckpt_dir, step, host_tree, meta, sync)

    def work():
        try:
            g.execute()
            g.assert_partial_order()
        except BaseException as e:                       # noqa: BLE001
            # never leave waiters blocked OR fooled: ready/test()/wait()
            # re-raise this as a FatalError — a failed commit can never
            # look like a landed checkpoint
            sync.fail(e)
            raise

    _EXECUTOR.submit(work)
    return sync


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like`` (shapes may be abstract).

    Verifies content hashes; raises FatalError on mismatch/corruption.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FatalError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for kp, like in flat:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        info = manifest["leaves"].get(name)
        if info is None:
            raise FatalError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(path, name + ".npy"))
        if _sha(arr) != info["sha256"]:
            raise FatalError(f"checkpoint leaf {name} corrupt (hash)")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def restore_resharded(ckpt_dir: str, tree_like: Any, shardings: Any,
                      step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Elastic restore: place every leaf with the NEW mesh's sharding.

    ``shardings`` is a pytree of jax.sharding.Sharding matching
    ``tree_like``; global shapes must agree with the manifest, the mesh
    need not (re-chunking is XLA's device_put).
    """
    tree, manifest = restore(ckpt_dir, tree_like, step)
    placed = jax.tree_util.tree_map(
        lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    return placed, manifest


@dataclasses.dataclass
class CheckpointStore:
    """Convenience wrapper used by the train loop."""

    directory: str
    keep_last: int = 3

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             *, blocking: bool = False):
        if blocking:
            save_sync(self.directory, step, tree, meta)
            self.gc()
            return None
        sync = save_async(self.directory, step, tree, meta)
        return sync

    def gc(self) -> None:
        """Drop all but the newest ``keep_last`` committed checkpoints."""
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, tree_like: Any, step: Optional[int] = None):
        return restore(self.directory, tree_like, step)
