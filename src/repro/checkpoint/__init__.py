from .store import (CheckpointStore, latest_step, restore, restore_resharded,
                    save_async, save_sync)

__all__ = ["CheckpointStore", "latest_step", "restore", "restore_resharded",
           "save_async", "save_sync"]
