"""AdamW with fp32 master weights and shard-local state (ZeRO-by-layout).

State tensors (``mu``, ``nu``, ``master``) mirror the parameter layout
exactly — TP-sharded over ``model``, FSDP-sharded over ``data`` — so the
optimizer never communicates: updates are element-wise on local shards.
At 104B params on 256 chips the at-rest per-chip cost is
``(2 + 4 + 4 + 4) · N / 256 ≈ 5.7 GB`` (bf16 param + fp32 master/mu/nu).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    use_master: bool = True           # fp32 master copy of bf16 params


@dataclasses.dataclass
class OptState:
    step: jax.Array                   # () int32
    mu: Dict[str, Any]
    nu: Dict[str, Any]
    master: Optional[Dict[str, Any]]  # fp32 params (None if disabled)


jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.step, s.mu, s.nu, s.master), None),
    lambda _, c: OptState(*c))


def adamw_init(params: Dict[str, Any], cfg: AdamWConfig) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: with fp32 params, astype would alias the param buffer and
    # break donation (same buffer donated twice in the train step)
    master = (jax.tree_util.tree_map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        if cfg.use_master else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros),
                    master=master)


def _decay_mask(path: str) -> bool:
    """No weight decay on norms, biases, scalars (standard practice)."""
    lowered = path.lower()
    return not any(t in lowered for t in
                   ("norm", "bias", "a_log", "d_skip", "gate_attn",
                    "gate_mlp"))


def adamw_update(grads: Dict[str, Any], state: OptState,
                 params: Dict[str, Any], cfg: AdamWConfig
                 ) -> Tuple[Dict[str, Any], OptState]:
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    paths = _leaf_paths(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    flat_p = jax.tree_util.tree_leaves(
        state.master if state.master is not None else params)
    treedef = jax.tree_util.tree_structure(params)

    new_p, new_m, new_v = [], [], []
    for path, g, m, v, p in zip(paths, flat_g, flat_m, flat_v, flat_p):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * pf
        pf = pf - lr * upd
        new_p.append(pf)
        new_m.append(m)
        new_v.append(v)

    master = (jax.tree_util.tree_unflatten(treedef, new_p)
              if cfg.use_master else None)
    cast = jax.tree_util.tree_unflatten(treedef, new_p)
    dtypes = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda p: p.dtype, params))
    params_out = jax.tree_util.tree_unflatten(
        treedef, [x.astype(d) for x, d in zip(new_p, dtypes)])
    return params_out, OptState(
        step=step,
        mu=jax.tree_util.tree_unflatten(treedef, new_m),
        nu=jax.tree_util.tree_unflatten(treedef, new_v),
        master=master)


def _leaf_paths(tree: Dict[str, Any]) -> list:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("/".join(str(getattr(k, "key", k)) for k in kp))
    return paths
