"""Gradient synchronization under manual SPMD — the subtle part.

Inside ``shard_map``, reverse-mode AD already does *some* of the gradient
reduction for us, because the forward collectives have exact transposes:

* FSDP-dim params (``fsdp_axis`` set): the forward ``all_gather`` over data
  transposes to a reduce(-scatter) — the shard's grad arrives **already
  summed over the data axis**.
* TP-sharded params (``tp_axis`` set): each model rank's shard grad is its
  own — nothing to reduce over the model axis.
* *Replicated* dims are the ones AD cannot see: a weight used identically
  by every rank of an axis needs an explicit psum of its grad over that
  axis.

``grad_sync`` applies exactly the missing reductions, per ParamSpec, and
normalizes to the **mean over data shards**.  Getting this wrong is silent
(loss still goes down, just wrong) — tests/test_train.py checks
distributed grads == single-device grads for every family.

In LCI modes the data-axis reductions lower to the ring schedules of
:mod:`repro.core.collectives` (chunk streams the XLA scheduler overlaps
with the backward compute of the *next* layer — the paper's
computation/communication overlap at the gradient level).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from repro.core import collectives as C
from repro.distributed.comm import Comm, _axes
from repro.models.common import ParamSpec


def _psum_data(x: jax.Array, comm: Comm) -> jax.Array:
    for a in _axes(comm.data_axis):
        if x.ndim >= 1 and x.shape[0] % axis_size(a) == 0:
            x = C.all_reduce(x, a, comm.cfg)        # ring rs+ag in LCI modes
        else:
            x = jax.lax.psum(x, a)
    return x


def grad_sync(grads: Dict[str, Any], specs: Dict[str, Any], comm: Comm
              ) -> Dict[str, Any]:
    """Apply the missing reductions; result = mean over data shards."""
    dp = comm.dp

    def sync(g: jax.Array, spec: ParamSpec) -> jax.Array:
        if spec.tp_axis is None:
            g = comm.psum_model(g)
        if spec.fsdp_axis is None:
            g = _psum_data(g, comm)
        return (g / dp).astype(g.dtype)

    return jax.tree_util.tree_map(sync, grads, specs)


def global_norm(grads: Dict[str, Any], specs: Dict[str, Any], comm: Comm
                ) -> jax.Array:
    """Global L2 norm of the (synced) gradient across all shards.

    Replicated dims would be double-counted by a blind psum; each param's
    local sum-of-squares is weighted by 1/replication before the reduce.
    """
    tp, dp = comm.tp, comm.dp
    total = jnp.zeros((), jnp.float32)
    for g, spec in zip(jax.tree_util.tree_leaves(grads),
                       jax.tree_util.tree_leaves(
                           specs, is_leaf=lambda x: isinstance(x, ParamSpec))):
        w = 1.0
        if spec.tp_axis is None:
            w /= tp
        if spec.fsdp_axis is None:
            w /= dp
        gf = g.astype(jnp.float32)
        total = total + w * jnp.sum(gf * gf)
    return jnp.sqrt(comm.psum_all(total))


def clip_by_global_norm(grads, specs, comm: Comm, max_norm: float):
    gn = global_norm(grads, specs, comm)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return (jax.tree_util.tree_map(
        lambda g: (g * scale).astype(g.dtype), grads), gn)
