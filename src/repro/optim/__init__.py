from .adamw import AdamWConfig, OptState, adamw_init, adamw_update
from .schedules import cosine_schedule, linear_warmup
from .grad_sync import grad_sync, global_norm, clip_by_global_norm

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "linear_warmup", "grad_sync", "global_norm",
           "clip_by_global_norm"]
