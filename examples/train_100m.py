"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--tiny]

Uses the full production path: config -> model -> AdamW(fp32 master) ->
train loop with async checkpointing, straggler monitoring, metrics CSV,
and deterministic step-indexed data.  ``--tiny`` shrinks the model for a
fast smoke run; the default is a true ~100M-parameter model (CPU-slow but
real).  Resume: rerun the same command after an interrupt.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import SyntheticPipeline
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import make_train_step, train_state_init
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    if args.tiny:
        cfg = ModelConfig(name="lm-tiny", family="dense", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                          vocab=2048, tp_target=4, dtype=jnp.float32)
    else:
        # ~100M params: 12L x 640d x swiglu(1792) + 32k vocab (tied)
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=640, n_heads=10, n_kv_heads=5,
                          d_ff=1792, vocab=32000, tie_embeddings=True,
                          tp_target=4, dtype=jnp.float32)
    model = build_model(cfg)
    opt = AdamWConfig(lr=cosine_schedule(args.lr, 20, args.steps))
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params, {args.steps} steps "
          f"@ {args.seq}x{args.batch}")

    step_fn = jax.jit(make_train_step(model, specs, opt),
                      donate_argnums=(0,))
    pipe = SyntheticPipeline(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch, n_motifs=256,
                             motif_len=16)
    t0 = time.time()
    state, hist = train_loop(
        state, step_fn, pipe,
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=100, log_every=20,
                   metrics_csv=f"{args.ckpt_dir}/metrics.csv"),
        batch_transform=lambda b, s: {k: jnp.asarray(v)
                                      for k, v in b.items()})
    dt = time.time() - t0
    tok_s = args.steps * args.seq * args.batch / dt
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} | "
          f"{dt:.0f}s total, {tok_s:,.0f} tok/s on CPU")
    assert hist[-1]["loss"] < hist[0]["loss"], "did not learn"
    print("train_100m OK")


if __name__ == "__main__":
    main()
