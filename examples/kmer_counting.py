"""K-mer counting (paper §5.3): the HipMer stage on the LCI-X runtime.

    PYTHONPATH=src python examples/kmer_counting.py [--reads 2000] [--ranks 4]

Error-prone synthetic reads; k-mers travel as aggregated active messages
to hash-owner ranks; two traversals (Bloom filter, then exact hashmap);
counts verified against a direct oracle.
"""
import argparse
import time

from repro.apps.kmer import (generate_reads, reference_count,
                             run_kmer_count)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reads", type=int, default=2000)
    ap.add_argument("--read-len", type=int, default=80)
    ap.add_argument("--k", type=int, default=11)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--agg-bytes", type=int, default=8 * 1024)
    args = ap.parse_args()

    print(f"generating {args.reads} reads (len {args.read_len}, 1% errors)")
    reads = generate_reads(args.reads, args.read_len, seed=3)
    t0 = time.time()
    oracle = reference_count(reads, args.k)
    t_ref = time.time() - t0
    print(f"oracle: {len(oracle)} k-mers with >=2 occurrences "
          f"({t_ref:.2f}s single-threaded)")

    counts, stats = run_kmer_count(reads, args.k, args.ranks,
                                   agg_bytes=args.agg_bytes)
    wrong = sum(1 for k in oracle if counts.get(k, 0) != oracle[k])
    print(f"LCI-X {args.ranks} ranks: {stats.elapsed_s:.2f}s, "
          f"{stats.messages} messages, "
          f"{stats.aggregation_flushes} aggregation flushes")
    print(f"exactness: {len(oracle) - wrong}/{len(oracle)} counts correct")
    assert wrong == 0
    hist = {}
    for n in counts.values():
        hist[n] = hist.get(n, 0) + 1
    top = sorted(hist.items())[:8]
    print("histogram (count -> #kmers):", dict(top))
    print("kmer example OK")


if __name__ == "__main__":
    main()
