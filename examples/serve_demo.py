"""Serving demo: continuous batching with LCI admission semantics.

    PYTHONPATH=src python examples/serve_demo.py [--arch olmo-1b]

Builds the reduced (smoke) model, trains nothing — the demo is the
*engine*: paged-KV admission (packet pool), retry/backlog under page
pressure, completion queues for finished requests, greedy decode.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke
from repro.models.registry import build_model
from repro.serving import PagedKVAllocator, ServeScheduler
from repro.serving.engine import init_cache, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=[a for a in ARCH_NAMES])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.family == "vlm" or cfg.is_encdec:
        raise SystemExit("demo targets decoder-only archs")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} "
          f"({sum(x.size for x in jax.tree_util.tree_leaves(params)):,} "
          f"params)")

    cache = init_cache(cfg, 128, args.max_batch)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    box = {"cache": cache}

    def decode_fn(tokens, positions):
        pad = args.max_batch - len(tokens)
        toks = jnp.asarray(np.pad(tokens, (0, pad)), jnp.int32)
        nxt, box["cache"] = serve(params, box["cache"], toks)
        return np.asarray(nxt)[:len(tokens)]

    alloc = PagedKVAllocator(n_pages=48, page_size=16)   # page pressure!
    sched = ServeScheduler(decode_fn, max_batch=args.max_batch,
                           allocator=alloc)
    cq = sched.alloc_cq()      # unified comp API (routes via transport when present)
    rng = np.random.default_rng(0)
    t0 = time.time()
    backlogged = 0
    for i in range(args.requests):
        st = sched.submit(rng.integers(0, cfg.vocab, size=6),
                          args.max_new, comp=cq, allow_retry=False)
        backlogged += st.code.name == "POSTED_BACKLOG"
    print(f"submitted {args.requests} requests "
          f"({backlogged} parked in the backlog under page pressure)")
    rounds = 0
    while sched.completed < args.requests:
        sched.step()
        rounds += 1
        assert rounds < 10_000
    dt = time.time() - t0
    n_tok = 0
    while True:
        st = cq.pop()
        if st.is_retry():
            break
        n_tok += len(st.get_buffer())
    print(f"done: {n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s), "
          f"{rounds} engine rounds, free pages back to "
          f"{alloc.free_pages}/48")
    print("serve demo OK")


if __name__ == "__main__":
    main()
