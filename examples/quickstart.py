"""Quickstart: the LCI-X public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core concepts end to end on CPU:
  1. runtime + resources (endpoints, the unified completion objects)
  2. endpoint-centric posting / Table-1 (send-recv, AM, RMA put)
  3. the ternary done/posted/retry status protocol + OFF idiom
  4. ASYNC completion graphs (comm ops as nodes, progress-completed)
  5. striping and progress policies (DESIGN.md §8)
  6. multithreaded progress workers + thread-safe CQs (DESIGN.md §10)
  7. burst posting: post_many doorbells + the OFF .batch() spelling
     (DESIGN.md §11)
  8. the unified attribute system: layered overrides + get_attr
     introspection on every resource, with the old-kwarg -> attr
     migration table (DESIGN.md §12)
  9. fused doorbells: packed single-descriptor bursts + the bf16 wire
     compression toggle (DESIGN.md §13)
  10. pluggable transport backends: shm rings in-process, then a real
      two-OS-process run via the SPMD launcher (DESIGN.md §14)
  11. the telemetry plane: attr-controlled stage timers, the unified
      counter snapshot, and Chrome trace export (DESIGN.md §15)
  12. the chaos plane: attr-driven fault injection healed by the
      reliability protocol, and the rank-death fail-fast (DESIGN.md §16)
  13. the serving engine: continuous batching on the comm core — paged
      KV slots, burst token delivery, exactly-once drains (DESIGN.md §17)
  14. an in-graph ring collective under shard_map (the TPU adaptation)

Posting is endpoint-centric since the comp/graph redesign (DESIGN.md §9).
Before:  post_send_x(r0, 1, buf, 16, tag).device(dev)()
After:   ep0.post_send(1, buf, 16, tag)          # stripe picks the device
         post_send_x(r0, 1, buf, 16, tag).endpoint(ep0)()   # deferred form
The raw post_*_x(...).device(...) spelling still works — endpoints are the
porcelain over it, and the `.endpoint(...)` OFF option is what completion
graphs use for their comm nodes.
"""
import numpy as np

from repro.core import (CommConfig, LocalCluster, MatchingPolicy, post_am_x,
                        post_put_x, post_recv_x, post_send_x)


def main():
    # -- 1. runtime lifecycle (paper §3.2.2): no global init; allocate --
    cfg = CommConfig(inject_max_bytes=64, bufcopy_max_bytes=4096)
    cluster = LocalCluster(n_ranks=2, config=cfg)
    r0, r1 = cluster[0], cluster[1]
    print(f"ranks: {r0.get_rank_me()}/{r0.get_rank_n()}")
    # a symmetric 2-device endpoint bundle on every rank: all posting
    # below rides these (stripe policy picks the device per op)
    eps = cluster.alloc_endpoint(n_devices=2, stripe="round_robin",
                                 name="quickstart")
    ep0, ep1 = eps

    # -- 2a. active messages with a remote completion queue ------------
    rcq = r1.alloc_cq()               # unified comp: signal/test/wait
    rcomp = r1.register_rcomp(rcq)
    status = ep0.post_am(1, np.arange(8, dtype=np.uint8), remote_comp=rcomp,
                         tag=42)
    print(f"inject AM -> {status.kind.name} (done = completed immediately)")
    msg = rcq.wait(cluster)           # progress-driven wait pops one status
    print(f"delivered: tag={msg.tag} payload={msg.get_buffer()[:4]}...")

    # -- 2b. send/recv with wildcard matching (OFF form: the wildcard
    #        matching policy is an option, endpoint= routes the device) --
    buf = np.zeros(16, np.uint8)
    post_recv_x(r1, 0, buf, 16, 0).matching_policy(
        MatchingPolicy.RANK_ONLY).endpoint(ep1)()
    post_send_x(r0, 1, np.full(16, 7, np.uint8), 16, 999).matching_policy(
        MatchingPolicy.RANK_ONLY).endpoint(ep0)()
    cluster.quiesce()
    print(f"wildcard recv got: {buf[:4]}...")

    # -- 2c. RMA put into registered memory -----------------------------
    target = np.zeros(32, np.uint8)
    region = r1.register_memory(target)
    ep0.post_put(1, np.arange(32, dtype=np.uint8), (region.rid, 0), 32)
    cluster.quiesce()
    print(f"RMA put landed: {target[:4]}...")

    # -- 3. back-pressure: retry is a value, not an exception -----------
    tiny = LocalCluster(2, cfg, fabric_depth=1)
    tiny[0]
    post_send_x(tiny[0], 1, np.zeros(8, np.uint8), 8, 0)()
    st = post_send_x(tiny[0], 1, np.zeros(8, np.uint8), 8, 0)()
    print(f"full fabric -> {st.kind.name} ({st.code.name}): caller decides")

    # -- 4. ASYNC completion graph: comm ops as graph nodes --------------
    #       An unfired OFF builder is a node; graph.start() posts ready
    #       nodes, the progress engine signals completions, descendants
    #       fire as signals arrive.  No host-side synchronous fire.
    g = r0.alloc_graph("demo")
    inbox = np.zeros(16, np.uint8)
    recv = g.add_comm(post_recv_x(r1, 0, inbox, 16, 7).endpoint(ep1),
                      name="recv")
    send = g.add_comm(post_send_x(r0, 1, np.full(16, 3, np.uint8), 16,
                                  7).endpoint(ep0), name="send")
    summed = g.add_node(lambda r, s: int(inbox.sum()), deps=[recv, send])
    g.start()                         # posts the comm nodes
    ready, _ = g.test()               # non-blocking probe
    vals = g.wait()                   # drives the cluster's progress
    g.assert_partial_order()
    print(f"async graph: started ready={ready}, sum={vals[summed]} "
          f"(fire order {g.fire_order}); execute() is now a shim over "
          f"start+wait")

    # -- 5. striping: by_peer/by_size isolate traffic classes; progress
    #       stays explicit: nothing moves until someone drives devices ---
    for i in range(4):
        ep0.post_am(1, np.full(8, i, np.uint8), remote_comp=rcomp)
    while eps[0].progress() + eps[1].progress():
        pass                          # explicit, client-driven progress
    print(f"endpoint striping: posts/device = "
          f"{[d['posts'] for d in ep0.counters()['devices']]}")
    while not rcq.pop().is_retry():
        pass                          # drain the demo deliveries

    # -- 6. multithreaded progress (paper §4.2.3): progress="workers"
    #       spawns N real threads that drive the endpoint's devices
    #       through per-device try-locks — a thread that fails a lock
    #       moves on.  Worker-signaled queues must be thread-safe:
    #       alloc_cq(threadsafe=True) is the paper's §4.1.4 FAA queue. --
    import dataclasses
    import time

    from repro.core import EndpointSpec
    wspec = EndpointSpec(name="workers-demo", n_devices=2,
                         progress="workers", n_workers=2)
    # symmetric bundles (streams match by device index), each with its
    # own worker threads: rank0's push the wire, rank1's deliver
    wep0 = r0.alloc_endpoint(spec=wspec)
    wep1 = r1.alloc_endpoint(spec=dataclasses.replace(wspec,
                                                      name="workers-demo@1"))
    wcq = r1.alloc_cq(threadsafe=True)
    wrc = r1.register_rcomp(wcq)
    with wep0, wep1:                  # starts/stops the worker threads
        for i in range(8):
            wep0.post_am(1, np.full(8, i, np.uint8), remote_comp=wrc)
        while wcq.pushes < 8:         # the workers deliver; we just wait
            time.sleep(1e-4)
    print(f"worker threads delivered {wcq.pushes} AMs (lock skips: "
          f"{wep1.counters()['workers']['lock_skips']})")

    # -- 7. burst posting (paper §4.3, DESIGN.md §11): a windowed hot
    #       loop coalesces K posts into one doorbell per stripe device —
    #       one packet-pool grab, one stacked payload copy, one fabric
    #       push, one telemetry bump, instead of one of each per message.
    #       A mid-burst retry splits the doorbell prefix-accept: re-post
    #       the failed suffix after driving progress. --------------------
    bursty = np.stack([np.full(8, i, np.uint8) for i in range(32)])
    statuses = ep0.post_am_many(1, list(bursty), rcomp,
                                tags=list(range(32)))
    pending = [s for s in statuses if s.is_retry()]
    while eps[0].progress() + eps[1].progress():
        pass
    delivered = 0
    while not rcq.pop().is_retry():
        delivered += 1
    print(f"burst posting: {delivered}/32 AMs in "
          f"{r0.engine.burst_posts} doorbell(s), {len(pending)} to re-post")

    # the OFF spelling batches deferred ops the same way
    batch = post_send_x(r0, 1, np.full(8, 1, np.uint8), 8, 70).endpoint(
        ep0).batch()
    post_send_x(r0, 1, np.full(8, 2, np.uint8), 8, 71).endpoint(
        ep0).batch(batch)
    got = [np.zeros(8, np.uint8), np.zeros(8, np.uint8)]
    sync2 = r1.alloc_sync(expected=2)
    for tag, buf in zip((70, 71), got):
        post_recv_x(r1, 0, buf, 8, tag, sync2)()
    batch.flush()                     # one doorbell for both sends
    sync2.wait(cluster)
    print(f"OFF .batch(): delivered {got[0][0]}, {got[1][0]} in order")

    # -- 8. the unified attribute system (DESIGN.md §12): every knob is
    #       one registry entry, resolved defaults -> REPRO_ATTR_* env ->
    #       LocalCluster(attrs=...) -> per-alloc named overrides, and
    #       queryable on every live resource via get_attr/.attrs.
    #
    #       old kwarg spelling                  -> attribute name
    #       ----------------------------------------------------------
    #       CommConfig(inject_max_bytes=...)    -> eager_max_bytes
    #       CommConfig(bufcopy_max_bytes=...)   -> rdv_threshold
    #       CommConfig(n_channels=...)          -> n_channels
    #       CommConfig(packets_per_lane=...)    -> packets_per_lane
    #       CommConfig(packet_bytes=...)        -> packet_bytes
    #       LocalCluster(fabric_depth=...)      -> fabric_depth
    #       LocalCluster(link_latency=...)      -> link_latency
    #       alloc_cq(capacity=...)              -> cq_capacity
    #       EndpointSpec(n_devices/stripe/...)  -> n_devices/stripe/
    #                                              progress/n_workers
    #       ProgressWorkerPool(burst=...)       -> worker_burst
    #       (old spellings keep working as deprecation shims) -----------
    tuned = LocalCluster(2, attrs={"eager_max_bytes": 16,
                                   "cq_capacity": 32})
    tcq = tuned[0].alloc_cq()                      # runtime layer: 32
    print(f"attrs: eager_max_bytes="
          f"{tuned[0].get_attr('eager_max_bytes')} "
          f"(source {tuned[0].attr_source('eager_max_bytes')}), "
          f"cq_capacity={tcq.get_attr('cq_capacity')}, "
          f"pool free_packets={tuned[0].get_attr('free_packets')}")
    tep = tuned[0].alloc_endpoint(stripe="by_size")   # per-alloc override
    print(f"attrs: endpoint stripe={tep.get_attr('stripe')} "
          f"width={tep.get_attr('width')}; try "
          f"REPRO_ATTR_RDV_THRESHOLD=64 python examples/quickstart.py "
          f"to flip bulk sends to rendezvous")

    # -- 9. fused doorbells (DESIGN.md §13): eager bursts of >=
    #       fused_min_burst uniform ops collapse into ONE packed wire
    #       descriptor (one stage-copy, one push, one matching probe),
    #       and wire_bf16 folds f32->bf16 wire compression into that
    #       same staging copy — delivered payloads come back as f32. --
    fcl = LocalCluster(2, attrs={"eager_max_bytes": 64,
                                 "wire_bf16": True})
    feps = fcl.alloc_endpoint(n_devices=1, name="fused")
    print(f"attrs: doorbell_fused={fcl[0].get_attr('doorbell_fused')} "
          f"fused_min_burst={fcl[0].get_attr('fused_min_burst')} "
          f"wire_bf16={fcl[0].get_attr('wire_bf16')}")
    fcq = fcl[1].alloc_cq()
    frc = fcl[1].register_rcomp(fcq)
    fbufs = [np.linspace(0, 1, 4, dtype=np.float32)] * 8
    fsts = feps[0].post_am_many(1, fbufs, frc)     # one fused doorbell
    feps[1].progress()
    delivered = 0
    while fcq.pop().is_done():
        delivered += 1
    print(f"fused doorbell: {sum(1 for s in fsts if s.is_done())} posted "
          f"-> {delivered} delivered as f32 over a bf16 wire "
          f"({fcl[0].fabric.pushes} rows on 1 descriptor); flip it off "
          f"with attrs={{'doorbell_fused': False}} or "
          f"REPRO_ATTR_DOORBELL_FUSED=0")

    # -- 10. transport backends (DESIGN.md §14): the fabric is an attr.
    #       "sim" (default) is the in-process deque fabric every section
    #       above used; "shm" swaps in mmap'd SPSC ring buffers with a
    #       stable wire codec — same API, real bytes. -------------------
    tcl = LocalCluster(2, attrs={"fabric_backend": "shm"})
    tcq = tcl[1].alloc_cq()
    trc = tcl[1].register_rcomp(tcq)
    post_am_x(tcl[0], 1, np.arange(8, dtype=np.uint8), None, None, trc)()
    tcl.quiesce()
    st = tcq.pop()
    print(f"shm backend: backend={tcl.fabric.backend} "
          f"(source={tcl.attr_source('fabric_backend')}), AM delivered "
          f"through a {tcl.get_attr('shm_ring_bytes')}-byte ring: "
          f"{st.is_done()}")
    tcl.close()                       # unlinks the ring session dir
    #       The same backend spans OS processes: the SPMD launcher forks
    #       N ranks that meet in a shared ring session (the paper's
    #       process mode, Figures 2/3).  Timeout-bounded — a wedged rank
    #       is reaped, never hung on.
    import subprocess
    import sys as _sys
    demo = subprocess.run(
        [_sys.executable, "-m", "repro.launch.spmd", "--ranks", "2",
         "--backend", "shm", "--iters", "10", "--timeout", "60"],
        capture_output=True, text=True, timeout=90)
    print(f"spmd 2-process shm demo: exit={demo.returncode}")
    for line in demo.stdout.splitlines():
        if "spmd-demo" in line:
            print(f"  {line}")

    # -- 11. the telemetry plane (DESIGN.md §15): observability is an
    #       attr.  telemetry_level=off (default) is a one-branch no-op
    #       on every hot path; "counters" unifies every legacy counter
    #       into one snapshot; "timers" adds per-stage span histograms;
    #       "trace" adds a Chrome-loadable timeline. -------------------
    import json as _json
    import tempfile as _tempfile
    ocl = LocalCluster(2, attrs={"telemetry_level": "trace",
                                 "eager_max_bytes": 1})  # bufcopy -> pool
    ocq = ocl[1].alloc_cq()
    orc = ocl[1].register_rcomp(ocq)
    for _ in range(32):
        post_am_x(ocl[0], 1, np.zeros(8, np.uint8), None, None, orc)()
        ocl.progress_all()
        while ocq.pop().is_done():
            pass
    ocl.quiesce()
    snap = ocl.telemetry_snapshot()   # mergeable across ranks/processes
    stages = sorted(snap["spans"])
    print(f"telemetry: level={ocl.get_attr('telemetry_level')} "
          f"({len(stages)} stages timed): {', '.join(stages[:6])}, ...")
    post_us = snap["spans"]["post"]["sum"] / 1e3
    print(f"telemetry: post count={snap['spans']['post']['count']} "
          f"total={post_us:.1f}us; counters: "
          f"device.posts={snap['counters']['device.posts']} "
          f"pool.gets={snap['counters']['pool.gets']}")
    # every resource carries its slice as a readonly attr
    print(f"telemetry: device attr block -> "
          f"{ocl[0].default_device.get_attr('telemetry')['counters']}")
    with _tempfile.TemporaryDirectory() as td:
        path = ocl.export_trace(f"{td}/trace.json")
        n_ev = len(_json.load(open(path))["traceEvents"])
        print(f"telemetry: exported {n_ev} Chrome trace_event slices "
              f"(load at chrome://tracing); try "
              f"REPRO_ATTR_TELEMETRY_LEVEL=timers on any benchmark")

    # -- 12. the chaos plane (DESIGN.md §16): faults are attrs too.
    #       Non-zero chaos_* wraps the fabric in a fault-injecting
    #       transport; reliability="auto" arms seq-stamping, cumulative
    #       acks, and retransmit — so 5% drop + dup + reorder still
    #       delivers exactly-once, in order.  REPRO_ATTR_CHAOS_DROP=0.05
    #       does the same to any run from the environment. -------------
    ccl = LocalCluster(2, attrs={"chaos_drop": 0.05, "chaos_dup": 0.05,
                                 "chaos_reorder": 0.05, "chaos_seed": 7})
    ccq = ccl[1].alloc_cq()
    crc = ccl[1].register_rcomp(ccq)
    for i in range(200):
        st = post_am_x(ccl[0], 1, np.full(32, i % 256, np.uint8), None,
                       None, crc).tag(i)()
        while st.is_retry():
            ccl.progress_all()
            st = post_am_x(ccl[0], 1, np.full(32, i % 256, np.uint8),
                           None, None, crc).tag(i)()
    ccl.quiesce()                     # drives retransmits until healed
    ctags = []
    while True:
        st = ccq.pop()
        if st.is_retry():
            break
        ctags.append(st.tag)
    faults = ccl.fabric.fault_counters()
    rel = ccl[0].rel.counters()
    assert ctags == list(range(200)), "chaos beat the reliability plane"
    print(f"chaos: 200/200 delivered in order despite "
          f"{faults['dropped']} drops, {faults['duped']} dups, "
          f"{faults['reordered']} reorders "
          f"({rel['retransmits']} retransmits, "
          f"{ccl[1].rel.counters()['dups_dropped']} dups swallowed); "
          f"try REPRO_ATTR_CHAOS_DROP=0.05 on the whole test suite")
    # rank death is the fault the protocol can't heal — it fails fast
    # instead: posts toward a dead peer err ERR_PEER_DEAD at post time,
    # outstanding ones complete ERR_PEER_DEAD on the next sweep (the
    # no-hang guarantee).  The SPMD launcher's --chaos-kill drives the
    # full recovery: heartbeat detection -> shrink_mesh -> resharded
    # restore (see python -m repro.launch.spmd --help).
    ccl[0].mark_peer_dead(1)
    st = post_am_x(ccl[0], 1, np.zeros(8, np.uint8), None, None, crc)()
    print(f"chaos: post to dead peer -> {st.code.name} at post time")
    ccl.close()

    # -- 13. the serving engine (DESIGN.md §17): continuous batching
    #       whose whole data plane is the comm core.  Prompts ride a
    #       by_size prefill endpoint, token returns a separate decode
    #       endpoint; every engine tick is a CompletionGraph whose
    #       first-token posts are comm NODES; decode steps burst their
    #       16-byte token rows through post_am_many; drain worker
    #       threads pop the thread-safe result CQ; and the paged-KV
    #       geometry is all attrs with get_attr introspection. ----------
    from repro.serving import (ContinuousBatcher, ServePlane,
                               SyntheticModel, TokenClient)
    scl = LocalCluster(2)
    plane = ServePlane(scl)           # rank 0 client, rank 1 server
    model = SyntheticModel(seed=7)    # deterministic token oracle
    server = ContinuousBatcher(plane, model, kv_slots=4, kv_page_tokens=8,
                               kv_evict="preempt_longest")
    sclient = TokenClient(plane, model, drain_workers=2)
    rng = np.random.default_rng(7)
    for _ in range(12):
        prompt = rng.integers(0, 32000, rng.integers(4, 40)).astype(np.int32)
        max_new = int(rng.integers(1, 9))
        rid, st = sclient.submit(prompt, max_new)
        while st.is_retry():
            server.step()
            rid, st = sclient.submit(prompt, max_new, rid=rid)
    while not (server.completed >= 12 and server.idle):
        server.step()                 # prefill/decode/deliver interleave
    while sclient.drain.drained < sclient.expected_tokens:
        sclient.pump()
    report = sclient.collect()        # verifies vs the model oracle
    assert report["lost"] == report["duplicated"] == 0, report
    print(f"serving: {report['completed']}/12 streams exactly-once, "
          f"{report['tokens']} tokens, {server.slots.preemptions} "
          f"preemptions, kv_slots={server.get_attr('kv_slots')} -> see "
          f"benchmarks/serve_traffic.py for the 1k-client open loop")
    scl.close()

    # -- 14. the in-graph layer: ring collectives (run under shard_map on
    #       real meshes; here single-device degenerates to local math) ---
    import jax.numpy as jnp
    from repro.distributed.comm import local_comm
    comm = local_comm()
    x = jnp.ones((8, 4))
    w = jnp.ones((4, 4))
    y = comm.ag_matmul(x, w)          # on a mesh: ring all-gather matmul
    print(f"ag_matmul: {y.shape}, comm degenerates locally; "
          f"see launch/dryrun.py for the 512-chip meshes")
    print("quickstart OK")


if __name__ == "__main__":
    main()
