"""Quickstart: the LCI-X public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core concepts end to end on CPU:
  1. runtime + resources (devices, completion queues, handlers)
  2. post_comm / Table-1 (send-recv, active messages, RMA put)
  3. the ternary done/posted/retry status protocol + OFF idiom
  4. completion graphs (DAG-scheduled comm + compute)
  5. endpoints and progress (striped multi-device bundles, DESIGN.md §8)
  6. an in-graph ring collective under shard_map (the TPU adaptation)
"""
import numpy as np

from repro.core import (CommConfig, CompletionGraph, LocalCluster,
                        MatchingPolicy, post_am_x, post_put_x, post_recv_x,
                        post_send_x)


def main():
    # -- 1. runtime lifecycle (paper §3.2.2): no global init; allocate --
    cfg = CommConfig(inject_max_bytes=64, bufcopy_max_bytes=4096)
    cluster = LocalCluster(n_ranks=2, config=cfg)
    r0, r1 = cluster[0], cluster[1]
    print(f"ranks: {r0.get_rank_me()}/{r0.get_rank_n()}")

    # -- 2a. active messages with a remote completion queue ------------
    rcq = r1.alloc_cq()
    rcomp = r1.register_rcomp(rcq)
    status = post_am_x(r0, 1, np.arange(8, dtype=np.uint8), None,
                       None, rcomp).tag(42)()       # OFF: options any order
    print(f"inject AM -> {status.kind.name} (done = completed immediately)")
    cluster.quiesce()
    msg = rcq.pop()
    print(f"delivered: tag={msg.tag} payload={msg.get_buffer()[:4]}...")

    # -- 2b. send/recv with wildcard matching ---------------------------
    buf = np.zeros(16, np.uint8)
    post_recv_x(r1, 0, buf, 16, 0).matching_policy(
        MatchingPolicy.RANK_ONLY)()
    post_send_x(r0, 1, np.full(16, 7, np.uint8), 16, 999).matching_policy(
        MatchingPolicy.RANK_ONLY)()
    cluster.quiesce()
    print(f"wildcard recv got: {buf[:4]}...")

    # -- 2c. RMA put into registered memory -----------------------------
    target = np.zeros(32, np.uint8)
    region = r1.register_memory(target)
    post_put_x(r0, 1, np.arange(32, dtype=np.uint8), (region.rid, 0), 32)()
    cluster.quiesce()
    print(f"RMA put landed: {target[:4]}...")

    # -- 3. back-pressure: retry is a value, not an exception -----------
    tiny = LocalCluster(2, cfg, fabric_depth=1)
    tiny[0]
    post_send_x(tiny[0], 1, np.zeros(8, np.uint8), 8, 0)()
    st = post_send_x(tiny[0], 1, np.zeros(8, np.uint8), 8, 0)()
    print(f"full fabric -> {st.kind.name} ({st.code.name}): caller decides")

    # -- 4. completion graph: partial-order comm + compute ---------------
    g = CompletionGraph("demo")
    a = g.add_node(lambda: np.arange(4.0))
    b = g.add_node(lambda: np.ones(4))
    c = g.add_node(lambda x, y: x @ y, deps=[a, b])     # fires when ready
    vals = g.execute()
    print(f"graph result: {vals[c]} (fire order {g.fire_order})")

    # -- 5. endpoints and progress: devices are replicable resources; an
    #       Endpoint is a named bundle of N of them with a striping policy
    #       (which device each op rides) and a progress policy (who drives
    #       them).  Progress stays explicit: nothing moves until someone
    #       drives the endpoint's devices. -------------------------------
    eps = cluster.alloc_endpoint(n_devices=2, stripe="by_peer",
                                 progress="dedicated", name="demo")
    ep0 = eps[0]                      # rank 0's side of the bundle
    for i in range(4):
        ep0.post_am(1, np.full(8, i, np.uint8), remote_comp=rcomp)
    while eps[0].progress() + eps[1].progress():
        pass                          # explicit, client-driven progress
    print(f"endpoint striping: posts/device = "
          f"{[d['posts'] for d in ep0.counters()['devices']]}")
    while not rcq.pop().is_retry():
        pass                          # drain the demo deliveries

    # -- 6. the in-graph layer: ring collectives (run under shard_map on
    #       real meshes; here single-device degenerates to local math) ---
    import jax.numpy as jnp
    from repro.distributed.comm import local_comm
    comm = local_comm()
    x = jnp.ones((8, 4))
    w = jnp.ones((4, 4))
    y = comm.ag_matmul(x, w)          # on a mesh: ring all-gather matmul
    print(f"ag_matmul: {y.shape}, comm degenerates locally; "
          f"see launch/dryrun.py for the 512-chip meshes")
    print("quickstart OK")


if __name__ == "__main__":
    main()
